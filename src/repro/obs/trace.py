"""Ring-buffer span/event recorder with a dual timeline.

One :class:`TraceRecorder` serves one engine.  Every recording helper is
a plain host-side append of already-materialised values — a perf_counter
stamp the engine took anyway, a virtual-clock float the transport just
computed, an int the return-link sync already brought to host.  Nothing
here may touch a device value or run under jit tracing (the
``obs-hot-path`` lint rule enforces both), and every call site in the
serving core is gated on ``recorder is not None`` so the hot path pays
zero when tracing is off.

Two clocks, tagged per event:

* ``"wall"`` — ``time.perf_counter()`` seconds.  Engine step phases,
  pipe ticks, offload windows, per-request latency stamps.
* ``"virtual"`` — the transport layer's :class:`~repro.distributed
  .transport.VirtualClock` seconds.  Per-stage busy windows, per-link
  transfers, stall ledger entries.  A 64 ms WAN run records a 64 ms
  timeline while costing CPU-milliseconds of wall time.

**Ledger events** (``link_send`` / ``tick_stall``) are recorded at the
exact sites where :class:`SimulatedLinkTransport` accumulates its books:
summing the recorded ``nbytes`` ints reproduces ``wire_bytes``
*bitwise*, counting the sends reproduces ``sends``, and summing the
per-tick ``tick_stall`` floats left-to-right reproduces ``stall_s``
bitwise (same floats added in the same order).  ``tests/test_obs.py``
and the acceptance timeline check both reconcile through
:meth:`TraceRecorder.link_ledger`.

The event buffer is a bounded ring (``capacity`` events, oldest evicted
first; ``dropped`` counts evictions — never a silent cap).  Per-request
traces live in a separate bounded dict keyed by request id and surface
on ``RequestOutput.trace``.
"""

from __future__ import annotations

import time
from collections import deque
from typing import Dict, List, NamedTuple, Optional, Tuple

__all__ = ["Event", "TraceRecorder", "WALL", "VIRTUAL"]

WALL = "wall"
VIRTUAL = "virtual"

# event kinds -> how repro.obs.timeline renders them
SPAN = "span"          # complete slice (ph "X")
ASYNC = "async"        # possibly-overlapping slice (ph "b"/"e" pair)
INSTANT = "instant"    # point event (ph "i")
COUNTER = "counter"    # sampled value (ph "C")


class Event(NamedTuple):
    """One recorded event.  ``data`` is a tuple of ``(key, value)``
    pairs (immutable, cheap to build, dict-able at export time)."""
    kind: str
    name: str
    clock: str
    track: str
    t0: float
    dur: float
    data: Tuple


class TraceRecorder:
    """Bounded flight recorder threaded through the serving stack.

    ``capacity`` bounds the event ring; ``max_requests`` bounds the
    per-request trace table (oldest *finished* entries evicted first).
    All helpers are safe to call from the engine's single-threaded step
    loop; the online pump serialises its calls behind the engine lock.
    """

    def __init__(self, capacity: int = 65536, max_requests: int = 4096):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.events: deque = deque(maxlen=capacity)
        self.dropped = 0
        self.max_requests = max_requests
        self.requests: Dict[int, dict] = {}
        self._finished_order: deque = deque()
        self.created_at = time.perf_counter()

    # -- core appends -----------------------------------------------------

    def _append(self, kind, name, clock, track, t0, dur, data) -> None:
        ev = self.events
        if len(ev) == self.capacity:
            self.dropped += 1
        ev.append(Event(kind, name, clock, track, t0, dur, data))

    def span(self, name: str, track: str, t0: float, t1: float,
             clock: str = WALL, data: Tuple = ()) -> None:
        self._append(SPAN, name, clock, track, t0, t1 - t0, data)

    def instant(self, name: str, track: str, t: float,
                clock: str = WALL, data: Tuple = ()) -> None:
        self._append(INSTANT, name, clock, track, t, 0.0, data)

    # -- engine step phases (wall clock) ----------------------------------

    def step_phase(self, name: str, t0: float, t1: float,
                   step: int) -> None:
        """One phase of one engine step: "reap" / "prefill" / "decode" /
        "admit" — recorded from the stamps ``OfflineEngine.step`` takes
        for ``EngineStats`` anyway."""
        self._append(SPAN, name, WALL, "engine", t0, t1 - t0,
                     (("step", step),))

    def pipe_tick(self, plane: str, t0: float, t1: float,
                  occupancy: Tuple) -> None:
        """One backend pipe tick (wall): which microbatch/chunk sat in
        each stage slot this tick (host ints the scheduler holds)."""
        self._append(SPAN, "tick", WALL, f"pipe/{plane}", t0, t1 - t0,
                     (("occupancy", occupancy),))

    # -- transport ledger (virtual clock) ---------------------------------

    def link_send(self, plane: str, link: int, nbytes: int,
                  t0: float, t1: float, return_trip: bool = False) -> None:
        """One payload crossing one ring link — recorded at the exact
        site where the transport does ``wire_bytes += nbytes``, so the
        recorded ints sum to the book bitwise."""
        self._append(ASYNC, "return" if return_trip else "send", VIRTUAL,
                     f"link{link}", t0, t1 - t0,
                     (("plane", plane), ("nbytes", nbytes)))

    def tick_stall(self, plane: str, stall_s: float, t: float) -> None:
        """The per-tick stall total, the same float the transport adds to
        its ``stall_s`` book (one entry per ``tick()`` call, in call
        order, so a left-to-right sum reproduces the ledger bitwise)."""
        self._append(COUNTER, "stall", VIRTUAL, f"stall/{plane}", t, 0.0,
                     (("stall_s", stall_s),))

    def stage_busy(self, plane: str, stage: int, t0: float,
                   t1: float) -> None:
        """One stage's compute window on the virtual clock (start =
        max(prev done, input arrival), end = the transport's ``done[s]``
        — monotone per stage by construction)."""
        self._append(SPAN, "busy", VIRTUAL, f"stage{stage}", t0, t1 - t0,
                     (("plane", plane),))

    def link_ledger(self) -> Dict[str, float]:
        """Re-derive the transport books from the recorded ledger events:
        ``{"wire_bytes": int, "sends": int, "stall_s": float}``.  Exact
        (bitwise) against ``SimulatedLinkTransport`` when the ring has
        not evicted any ledger event (``dropped == 0``)."""
        wire = 0
        sends = 0
        stall = 0.0
        for e in self.events:
            if e.kind == ASYNC and e.name in ("send", "return"):
                wire += e.data[1][1]
                sends += 1
            elif e.kind == COUNTER and e.name == "stall":
                stall += e.data[0][1]
        return {"wire_bytes": wire, "sends": sends, "stall_s": stall}

    # -- offload windows (wall clock) -------------------------------------

    def offload_swap_out(self, mb: int, t: float, asynchronous: bool
                         ) -> None:
        self._append(INSTANT, "swap_out", WALL, "offload", t, 0.0,
                     (("mb", mb), ("async", asynchronous)))

    def offload_swap_in(self, mb: int, t0: float, t1: float) -> None:
        """The swap-in wait window: how long ``ensure_resident`` blocked
        on the staged copy (t1 - t0 is the part the double-buffer failed
        to hide under compute)."""
        self._append(SPAN, "swap_in", WALL, "offload", t0, t1 - t0,
                     (("mb", mb),))

    # -- scheduler decisions (wall clock) ---------------------------------

    def prefix_event(self, kind: str, request_id: int, tokens: int,
                     t: float) -> None:
        """Prefix-cache activity: kind is "hit" / "insert" / "evict"."""
        self._append(INSTANT, f"prefix_{kind}", WALL, "prefix", t, 0.0,
                     (("request_id", request_id), ("tokens", tokens)))

    def slo_budget(self, frac: float, budget: int, t: float) -> None:
        self._append(COUNTER, "slo_budget", WALL, "slo", t, 0.0,
                     (("frac", frac), ("budget", budget)))

    def fault(self, kind: str, t: float, data: Tuple = ()) -> None:
        """Fault injections and recoveries: kind is "drop" / "delay" /
        "recover"."""
        self._append(INSTANT, f"fault_{kind}", WALL, "faults", t, 0.0,
                     data)

    def reshard_span(self, phase: str, t0: float, t1: float,
                     data: Tuple = ()) -> None:
        """Reshard lifecycle: phase is "drain" / "rebuild"."""
        self._append(SPAN, f"reshard_{phase}", WALL, "reshard", t0,
                     t1 - t0, data)

    # -- per-request traces -----------------------------------------------

    def _req(self, request_id: int) -> Optional[dict]:
        return self.requests.get(request_id)

    def request_submit(self, request_id: int, t: float,
                       prompt_len: int) -> None:
        if len(self.requests) >= self.max_requests:
            while self._finished_order:
                old = self._finished_order.popleft()
                if self.requests.pop(old, None) is not None:
                    break
            else:
                return                      # table full of live requests
        self.requests[request_id] = {
            "request_id": request_id, "prompt_len": prompt_len,
            "submit_time": t, "admit_time": None,
            "first_token_time": None, "token_times": [],
            "chunks": 0, "pages": 0, "prefix_hit_tokens": 0,
            "finish_time": None, "finish_reason": None,
            # online (stream-side) stamps, when an OnlineLLM fronts the
            # engine: the SAME floats RequestStream holds, so derived
            # TTFT/ITL match the stream's reports bitwise
            "stream_submit_time": None, "delivery_times": [],
        }

    def request_admit(self, request_id: int, t: float) -> None:
        r = self._req(request_id)
        if r is not None and r["admit_time"] is None:
            r["admit_time"] = t

    def request_first_token(self, request_id: int, t: float) -> None:
        r = self._req(request_id)
        if r is not None and r["first_token_time"] is None:
            r["first_token_time"] = t

    def request_tokens(self, request_id: int, n: int, t: float) -> None:
        """``n`` tokens sampled for this request at engine-step stamp
        ``t`` (one stamp per step — the engine's own step-end clock)."""
        r = self._req(request_id)
        if r is not None:
            r["token_times"].extend([t] * n)

    def request_chunk(self, request_id: int, tokens: int) -> None:
        r = self._req(request_id)
        if r is not None:
            r["chunks"] += 1

    def request_pages(self, request_id: int, n: int) -> None:
        r = self._req(request_id)
        if r is not None:
            r["pages"] += n

    def request_prefix_hit(self, request_id: int, tokens: int) -> None:
        r = self._req(request_id)
        if r is not None:
            r["prefix_hit_tokens"] += tokens

    def request_finish(self, request_id: int, t: float,
                       reason: Optional[str]) -> None:
        r = self._req(request_id)
        if r is not None and r["finish_time"] is None:
            r["finish_time"] = t
            r["finish_reason"] = reason
            self._finished_order.append(request_id)
            if len(self._finished_order) > 4 * self.max_requests:
                # drop stale entries (already-evicted request ids)
                self._finished_order = deque(
                    rid for rid in self._finished_order
                    if rid in self.requests)

    # stream-side stamps (OnlineLLM): the exact floats RequestStream uses
    def request_stream_submit(self, request_id: int, t: float) -> None:
        r = self._req(request_id)
        if r is not None:
            r["stream_submit_time"] = t

    def request_delivery(self, request_id: int, t: float,
                         n: int = 1) -> None:
        r = self._req(request_id)
        if r is not None:
            r["delivery_times"].extend([t] * n)

    def request_trace(self, request_id: int) -> Optional[dict]:
        """Snapshot of one request's trace with derived latencies:
        ``queue_wait_s`` (submit → admitted into a slot), ``ttft_s``
        (submit → first token sampled; stream-side when online stamps
        exist), ``inter_token_s`` (consecutive token-stamp deltas)."""
        r = self._req(request_id)
        if r is None:
            return None
        out = dict(r)
        out["token_times"] = list(r["token_times"])
        out["delivery_times"] = list(r["delivery_times"])
        sub, adm = r["submit_time"], r["admit_time"]
        out["queue_wait_s"] = None if adm is None else adm - sub
        if r["delivery_times"] and r["stream_submit_time"] is not None:
            # stream-side: identical floats to RequestStream.ttft_s /
            # inter_token_s() — same stamps, same subtractions
            ts = r["delivery_times"]
            out["ttft_s"] = ts[0] - r["stream_submit_time"]
            out["inter_token_s"] = [b - a for a, b in zip(ts, ts[1:])]
        else:
            ft = r["first_token_time"]
            out["ttft_s"] = None if ft is None else ft - sub
            ts = r["token_times"]
            out["inter_token_s"] = [b - a for a, b in zip(ts, ts[1:])]
        return out

    # -- summaries --------------------------------------------------------

    def summary(self) -> Dict:
        return {"events": len(self.events), "dropped": self.dropped,
                "requests": len(self.requests),
                **self.link_ledger()}
