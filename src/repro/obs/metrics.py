"""Counter/gauge/histogram registry with snapshot deltas and
Prometheus-text / JSONL exposition.

:class:`Metrics` is a plain host-side registry — no background threads,
no device access.  ``update_from_engine`` maps the engine's own report
onto it: ``EngineStats`` fields become counters/gauges, the transport
books become gauges, and the per-stage ``StragglerMitigator``
observations (exposed by ``OfflineEngine.throughput_report()["stages"]``)
become per-stage labelled gauges.  Snapshots are cheap dicts, so a
serve loop can diff two of them (``Metrics.delta``) to get a per-window
rate report without resetting anything.

Exposition formats:

* :meth:`Metrics.prometheus_text` — the Prometheus text format
  (``# TYPE`` headers, ``name{label="v"} value`` samples, histogram
  ``_bucket``/``_sum``/``_count`` triplets) for scrape endpoints.
* :meth:`Metrics.jsonl_line` — one JSON object per call (flat
  ``{name: value}`` plus a wall stamp) for append-only log files.
"""

from __future__ import annotations

import json
import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = ["Metrics", "Counter", "Gauge", "Histogram",
           "update_from_engine"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")

# default histogram buckets: exponential seconds, serving-latency shaped
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)


def _sanitize(name: str) -> str:
    return _NAME_RE.sub("_", name)


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{_sanitize(k)}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Monotone accumulator.  ``inc`` with a negative amount raises —
    a counter that goes backward is a books bug, not a metric."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(
                f"counter {self.name} cannot decrease (inc {amount})")
        self.value += amount

    def set_to(self, value: float) -> None:
        """Advance to an externally-maintained monotone total (the
        engine keeps its own books; the metric mirrors them)."""
        if value < self.value:
            raise ValueError(
                f"counter {self.name} moved backward: "
                f"{self.value} -> {value}")
        self.value = value


class Gauge:
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)


class Histogram:
    """Fixed-bucket histogram (cumulative counts, Prometheus-style)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.help = help
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError(f"histogram {name} needs >= 1 bucket bound")
        self.counts = [0] * (len(self.buckets) + 1)   # +inf tail
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        v = float(value)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.buckets):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1


class Metrics:
    """The registry.  Metric identity is ``(name, labels)`` where labels
    is a tuple of ``(key, value)`` pairs; re-registering an existing
    identity returns the existing instrument (idempotent wiring)."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, Tuple], object] = {}

    # -- registration -----------------------------------------------------

    def _get(self, cls, name: str, labels: Dict[str, str], help: str,
             **kw):
        key = (name, tuple(sorted((labels or {}).items())))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, help=help, **kw)
            self._metrics[key] = m
        elif not isinstance(m, cls):
            raise ValueError(f"metric {name} already registered as "
                             f"{type(m).__name__}, not {cls.__name__}")
        return m

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None,
                help: str = "") -> Counter:
        return self._get(Counter, name, labels or {}, help)

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None,
              help: str = "") -> Gauge:
        return self._get(Gauge, name, labels or {}, help)

    def histogram(self, name: str,
                  labels: Optional[Dict[str, str]] = None, help: str = "",
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, labels or {}, help,
                         buckets=buckets)

    # -- snapshots --------------------------------------------------------

    def snapshot(self) -> Dict[str, float]:
        """Flat ``{name{labels}: value}`` — histograms contribute their
        ``_sum`` and ``_count`` series."""
        out: Dict[str, float] = {}
        for (name, labels), m in sorted(self._metrics.items()):
            tag = _sanitize(name) + _fmt_labels(labels)
            if isinstance(m, Histogram):
                out[tag + "_sum"] = m.sum
                out[tag + "_count"] = float(m.count)
            else:
                out[tag] = m.value
        return out

    @staticmethod
    def delta(prev: Dict[str, float],
              cur: Dict[str, float]) -> Dict[str, float]:
        """Per-key change between two snapshots (keys only in ``cur``
        count from zero) — the per-window rate numerator."""
        return {k: v - prev.get(k, 0.0) for k, v in cur.items()}

    # -- exposition -------------------------------------------------------

    def prometheus_text(self) -> str:
        lines: List[str] = []
        seen_type = set()
        for (name, labels), m in sorted(self._metrics.items()):
            sname = _sanitize(name)
            if sname not in seen_type:
                seen_type.add(sname)
                if m.help:
                    lines.append(f"# HELP {sname} {m.help}")
                lines.append(f"# TYPE {sname} {m.kind}")
            tag = _fmt_labels(labels)
            if isinstance(m, Histogram):
                cum = 0
                for b, c in zip(m.buckets, m.counts):
                    cum += c
                    lb = dict(labels)
                    lb["le"] = repr(float(b))
                    lines.append(f"{sname}_bucket"
                                 f"{_fmt_labels(tuple(sorted(lb.items())))}"
                                 f" {cum}")
                lb = dict(labels)
                lb["le"] = "+Inf"
                lines.append(f"{sname}_bucket"
                             f"{_fmt_labels(tuple(sorted(lb.items())))}"
                             f" {m.count}")
                lines.append(f"{sname}_sum{tag} {m.sum}")
                lines.append(f"{sname}_count{tag} {m.count}")
            else:
                lines.append(f"{sname}{tag} {m.value}")
        return "\n".join(lines) + ("\n" if lines else "")

    def jsonl_line(self) -> str:
        snap = self.snapshot()
        snap["_ts"] = time.time()
        return json.dumps(snap, sort_keys=True)


# ---------------------------------------------------------------------------
# Engine mapping
# ---------------------------------------------------------------------------


def update_from_engine(metrics: Metrics, engine) -> Dict[str, float]:
    """Map one engine report onto the registry and return the snapshot.

    Uses ``engine.throughput_report()`` — which (by contract, see
    ``EngineStats.status_counts``) refreshes the status counts — so a
    metrics scrape can never observe stale queue/decode occupancy.
    """
    rep = engine.throughput_report()
    for name, key in (("repro_tokens_total", "total_tokens"),
                      ("repro_decode_tokens_total", "decode_tokens"),
                      ("repro_prefill_tokens_total", "prefill_tokens"),
                      ("repro_requests_finished_total", "finished"),
                      ("repro_engine_steps_total", "steps"),
                      ("repro_offload_swaps_total", "swaps"),
                      ("repro_prefix_hits_total", "prefix_hits"),
                      ("repro_prefix_hit_tokens_total",
                       "prefix_hit_tokens")):
        if key in rep:
            metrics.counter(name).set_to(float(rep[key]))
    for name, key in (("repro_tok_per_s", "tok_per_s"),
                      ("repro_decode_tok_per_s", "decode_tok_per_s"),
                      ("repro_prefill_tok_per_s", "prefill_tok_per_s"),
                      ("repro_wall_time_s", "wall_time_s"),
                      ("repro_queue_depth", "queue_depth"),
                      ("repro_mean_latency_steps", "mean_latency_steps")):
        if key in rep:
            metrics.gauge(name).set(float(rep[key]))
    for status, n in (rep.get("status_counts") or {}).items():
        metrics.gauge("repro_requests",
                      labels={"status": str(status)}).set(float(n))
    for key, v in (rep.get("transport") or {}).items():
        if isinstance(v, (int, float)):
            metrics.gauge(f"repro_transport_{key}").set(float(v))
    stages = rep.get("stages") or {}
    for s, t in enumerate(stages.get("ewma_s", ())):
        metrics.gauge("repro_stage_time_ewma_s",
                      labels={"stage": str(s)}).set(float(t))
    for s, t in enumerate(stages.get("total_s", ())):
        metrics.gauge("repro_stage_time_total_s",
                      labels={"stage": str(s)}).set(float(t))
    for s, w in enumerate(stages.get("microbatch_weights", ())):
        metrics.gauge("repro_stage_admission_weight",
                      labels={"stage": str(s)}).set(float(w))
    stragglers = set(stages.get("stragglers", ()))
    for s in range(len(stages.get("ewma_s", ()))):
        metrics.gauge("repro_stage_straggler",
                      labels={"stage": str(s)}).set(
                          1.0 if s in stragglers else 0.0)
    return metrics.snapshot()
