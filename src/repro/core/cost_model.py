"""DeServe §3 cost/profit model (Tables 1 and 2).

The unit of account is one "compute resource unit" — an 8-GPU (or 8-chip)
pipeline serving the target model.  Profitability:  R > C·T  ⇔  M > C / P
with throughput M (tok/s), per-hour cost C, and unified per-token price P.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict

# Together.ai Llama-70B price used by the paper (USD per 1M tokens)
DEFAULT_PRICE_PER_MTOK = 0.90


@dataclass(frozen=True)
class ComputePlatform:
    name: str
    spec: str
    cost_per_hour: float          # USD, 8-GPU equivalent
    latency_class: str            # low | medium | high
    gpu_type: str
    availability: str


# Table 1 / Table 2 rows (paper values, accessed 2024-10-31)
PLATFORMS: Dict[str, ComputePlatform] = {
    "cloud": ComputePlatform(
        "cloud", "GCP-8x g2-standard-32 (L4)", 13.88, "low",
        "standardized", "99.9%+ uptime"),
    "runpod": ComputePlatform(
        "runpod", "RunPod-8x4090", 5.52, "medium",
        "heterogeneous", "variable uptime"),
    "ionet": ComputePlatform(
        "ionet", "io.net-8x4090", 3.69, "medium",
        "heterogeneous", "variable uptime"),
    "mining": ComputePlatform(
        "mining", "WhatToMine-8x4090", 0.35, "high",
        "heterogeneous", "intermittent"),
    # hardware-adaptation column: the TPU target this repo lowers for.
    # 8x v5e on-demand ≈ $1.2/chip-hr public list price.
    "tpu_v5e": ComputePlatform(
        "tpu_v5e", "8x TPU v5e (on-demand)", 9.60, "low",
        "standardized", "99.9%+ uptime"),
}


def min_throughput(cost_per_hour: float,
                   price_per_mtok: float = DEFAULT_PRICE_PER_MTOK) -> float:
    """Break-even total throughput in tokens/second:  M_min = C / P."""
    price_per_token = price_per_mtok / 1e6
    return cost_per_hour / 3600.0 / price_per_token


def profit_per_hour(throughput_tps: float, cost_per_hour: float,
                    price_per_mtok: float = DEFAULT_PRICE_PER_MTOK) -> float:
    revenue = throughput_tps * 3600.0 * price_per_mtok / 1e6
    return revenue - cost_per_hour


def is_profitable(throughput_tps: float, platform: str,
                  price_per_mtok: float = DEFAULT_PRICE_PER_MTOK) -> bool:
    return profit_per_hour(throughput_tps, PLATFORMS[platform].cost_per_hour,
                           price_per_mtok) > 0


def table2(price_per_mtok: float = DEFAULT_PRICE_PER_MTOK) -> Dict[str, dict]:
    """Reproduce paper Table 2."""
    return {
        name: {
            "spec": p.spec,
            "cost_per_hour": p.cost_per_hour,
            "price_per_mtok": price_per_mtok,
            "min_throughput_tps": min_throughput(p.cost_per_hour,
                                                 price_per_mtok),
        }
        for name, p in PLATFORMS.items()
    }


# Paper Table 2 reference values for validation (tokens/second)
PAPER_TABLE2 = {
    "cloud": 4283.33,
    "runpod": 1703.70,
    "ionet": 1138.89,
    "mining": 108.02,
}
