"""Inter-layer (pipeline) parallelism over the high-latency ``pod`` axis.

This is DeServe's distribution strategy (§2.3 + §4.3) mapped onto JAX SPMD:
the model's scanned periods are split into ``n_stages`` contiguous stage
slices (weights never cross the slow link), activations move stage→stage
with ``lax.ppermute`` inside a ``shard_map`` that is *manual over the pod
axis only* — data/tensor parallelism inside each pod stays automatic, so
each stage is itself a 256-chip DP×TP program.

Schedule: the §4.3 circular schedule with ``N_B`` microbatches in flight;
one call = one full pass (fill + steady + drain, ``T = N_B + N_S − 1``
ticks).  At tick ``t`` pod ``p`` works on microbatch ``t − p`` (when in
range); out-of-range ticks are pipeline bubbles — their cache writes are
masked.  The scheduler (``repro.core.scheduler``) picks ``N_B`` from the
measured stage time and link latency so that steady-state bubbles vanish;
here ``N_B`` is a static compile-time parameter, exactly as the paper's
implementation fixes it per deployment.

Stage assignment is period-aligned: ``pps = n_periods // n_stages`` scanned
periods per stage.  Leftover periods and the pattern tail run as a shared
*epilogue* — replicated across pods, TP/DP-sharded inside — after the
drained activations are returned (the return link the paper's driver also
pays).  For every assigned arch the epilogue is ≤ 2 layers (<6 % of
compute).
"""

from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import embedding as embed_lib
from repro.models import model as model_lib
from repro.models.common import Runtime, make_layer_plan, rms_norm


def _shard_map(f, *, mesh, axis_names, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: new-style (axis_names /
    check_vma) when present, else ``jax.experimental.shard_map`` with the
    complementary ``auto`` axis set (manual over ``axis_names`` only)."""
    if hasattr(jax, "shard_map"):
        # repro-audit: allow(retrace-jit) — trace-time only: callers wrap the tick in one outer jax.jit, so this wrapper is built once per compile, never per tick
        return jax.shard_map(f, mesh=mesh, axis_names=axis_names,
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    # the experimental API cannot partial-auto this body on older jax
    # (axis_index lowers to an unsupported PartitionId under SPMD
    # partitioning); every spec only references the manual axes, so run
    # fully manual — the remaining axes are replicated either way
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def _wire_permute(y, n_stages: int, wire_dtype: str):
    """Ship activations one stage downstream — the wire of §4.3's ring.

    ``wire_dtype="int8"`` packs the payload per row before the permute
    (one f32 scale per row travels with it) and dequantizes on arrival,
    so the bytes crossing the slow link are the packed ones the
    transport accounting prices.  ``"fp32"`` is the identity path: one
    ppermute of the raw activation, bit-identical to the pre-codec
    pipeline.  The branch is a trace-time Python ``if`` — each
    ``wire_dtype`` is its own compiled program, never a ``lax.cond``."""
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    if wire_dtype == "int8":
        from repro.distributed.compression import (int8_compress_rows,
                                                   int8_decompress_rows)
        q, scale = int8_compress_rows(y)
        q = jax.lax.ppermute(q, "pod", perm)
        scale = jax.lax.ppermute(scale, "pod", perm)
        return int8_decompress_rows(q, scale, y.dtype)
    return jax.lax.ppermute(y, "pod", perm)


@dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    n_microbatches: int
    mb_size: int                      # sequences per microbatch

    @property
    def global_batch(self) -> int:
        return self.n_microbatches * self.mb_size

    @property
    def n_ticks(self) -> int:
        return self.n_microbatches + self.n_stages - 1


# ---------------------------------------------------------------------------
# Parameter / cache splitting
# ---------------------------------------------------------------------------


def split_layers(cfg: ModelConfig, n_stages: int):
    """(periods_per_stage, leftover_periods).  Stage i owns scanned periods
    [i·pps, (i+1)·pps); the leftover periods + pattern tail are epilogue."""
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    pps = plan.n_periods // n_stages
    leftover = plan.n_periods - pps * n_stages
    if pps == 0:
        raise ValueError(
            f"{cfg.name}: {plan.n_periods} periods cannot fill {n_stages} "
            "pipeline stages")
    return pps, leftover


def split_scan_params(params: dict, cfg: ModelConfig, n_stages: int):
    """Split stacked scan params into (stage_params, epilogue_scan_params).

    stage leaves:    (n_stages, pps, ...)   — shard dim 0 over "pod"
    epilogue leaves: (leftover, ...) or None
    """
    pps, leftover = split_layers(cfg, n_stages)

    def split_leaf(x):
        stage = x[: pps * n_stages].reshape((n_stages, pps) + x.shape[1:])
        epi = x[pps * n_stages:] if leftover else None
        return stage, epi

    stage_list, epi_list = [], []
    for pos in params["scan"]:
        s = jax.tree.map(lambda x: split_leaf(x)[0], pos)
        e = jax.tree.map(lambda x: split_leaf(x)[1], pos) if leftover else None
        stage_list.append(s)
        epi_list.append(e)
    return stage_list, (epi_list if leftover else [])


def init_pipeline_caches(cfg: ModelConfig, pcfg: PipelineConfig,
                         capacity: int, rt: Runtime) -> dict:
    """Cache pytree for the pipelined server.

    stage caches:    leaves (n_stages, n_mb, pps, mb, ...)  [pod, none, ...]
    epilogue caches: standard model cache dict over the full global batch.
    """
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    pps, leftover = split_layers(cfg, pcfg.n_stages)
    stage = [
        model_lib._kind_cache(k, cfg, pcfg.mb_size, capacity, rt,
                              lead=(pcfg.n_stages, pcfg.n_microbatches, pps))
        for k in plan.period_kinds
    ]
    epi_scan = [
        model_lib._kind_cache(k, cfg, pcfg.global_batch, capacity, rt,
                              lead=(leftover,))
        for k in plan.period_kinds
    ] if leftover else []
    tail = [model_lib._kind_cache(k, cfg, pcfg.global_batch, capacity, rt)
            for k in plan.tail_kinds]
    return {"stage": stage, "epi_scan": epi_scan, "tail": tail}


# ---------------------------------------------------------------------------
# The pipelined pass (shared by decode and prefill)
# ---------------------------------------------------------------------------


def _pipeline_pass(stage_params, stage_caches, queue, positions_q, cfg, rt,
                   pcfg: PipelineConfig, mode: str):
    """Run one fill+drain pass of the circular schedule inside shard_map.

    queue:        (n_mb, mb, S, D) embedded microbatch inputs (replicated
                  w.r.t. pod; DP/TP-sharded inside).
    positions_q:  (n_mb, [3,] mb, S) per-microbatch positions.
    Returns (drained (n_mb, mb, S, D), new_stage_caches).
    """
    n_s, n_mb = pcfg.n_stages, pcfg.n_microbatches
    pps, _ = split_layers(cfg, n_s)
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)

    def body(local_params, local_caches, queue, positions_q):
        # local_params leaves: (1, pps, ...); local_caches: (1, n_mb, pps, ...)
        local_params = [jax.tree.map(lambda x: x[0], p) for p in local_params]
        local_caches = [jax.tree.map(lambda x: x[0], c) for c in local_caches]
        pod = jax.lax.axis_index("pod")
        is_last = pod == n_s - 1

        x0 = queue[0] * jnp.where(pod == 0, 1.0, 0.0).astype(queue.dtype)

        def tick(carry, t):
            x, caches, outs = carry
            mb_id = t - pod
            active = (mb_id >= 0) & (mb_id < n_mb)
            mb_c = jnp.clip(mb_id, 0, n_mb - 1)

            mb_caches = [jax.tree.map(
                lambda l: jax.lax.dynamic_index_in_dim(l, mb_c, 0,
                                                       keepdims=False), c)
                for c in caches]
            if positions_q.ndim == 4:          # (n_mb, 3, mb, S) m-rope
                pos = jax.lax.dynamic_index_in_dim(positions_q, mb_c, 0,
                                                   keepdims=False)
            else:
                pos = jax.lax.dynamic_index_in_dim(positions_q, mb_c, 0,
                                                   keepdims=False)
            # NOTE (SPerf iteration A4, refuted): wrapping this in
            # lax.cond(active, work, identity) to skip bubble-tick compute
            # REGRESSED the memory term 41% — the conditional materialises
            # its operand tuple (the whole per-mb cache) and blocks carry
            # aliasing.  Bubble writes are masked with where() instead.
            y, new_mb_caches = model_lib.run_periods(
                local_params, x, cfg, rt, period_kinds=plan.period_kinds,
                mode=mode, scan_caches=mb_caches, positions=pos)
            # mask bubble writes, splice the microbatch's caches back
            new_caches = []
            for c_all, c_old, c_new in zip(caches, mb_caches,
                                           new_mb_caches):
                c_new = jax.tree.map(
                    lambda n, o: jnp.where(active, n, o), c_new, c_old)
                new_caches.append(jax.tree.map(
                    lambda l, n: jax.lax.dynamic_update_index_in_dim(
                        l, n.astype(l.dtype), mb_c, 0), c_all, c_new))

            # collect the drained microbatch from the last pod
            out_id = t - (n_s - 1)
            out_c = jnp.clip(out_id, 0, n_mb - 1)
            contrib = jnp.where(is_last, y, jnp.zeros_like(y))
            old_slot = jax.lax.dynamic_index_in_dim(outs, out_c, 0,
                                                    keepdims=False)
            slot = jnp.where((out_id >= 0) & (out_id < n_mb), contrib,
                             old_slot)
            outs = jax.lax.dynamic_update_index_in_dim(outs, slot, out_c, 0)

            # ship activations around the ring; pod 0 takes the next inject
            y_next = jax.lax.ppermute(
                y, "pod", [(i, (i + 1) % n_s) for i in range(n_s)])
            nxt = jnp.clip(t + 1, 0, n_mb - 1)
            inj = jax.lax.dynamic_index_in_dim(queue, nxt, 0, keepdims=False)
            x_next = jnp.where(pod == 0, inj, y_next)
            return (x_next, new_caches, outs), None

        outs0 = jnp.zeros(queue.shape, queue.dtype)
        (x, new_caches, outs), _ = jax.lax.scan(
            tick, (x0, local_caches, outs0), jnp.arange(pcfg.n_ticks))
        # the drained buffer lives on the last pod; return it to everyone
        # (this is the paper's output return link — (n_mb, mb, S, D) once per
        # pass, not per tick).  f32 psum: XLA:CPU's AllReducePromotion pass
        # aborts cloning bf16 all-reduces emitted from partial-manual
        # shard_map (dtype identical on TPU after the pass anyway).
        outs = jax.lax.psum(
            jnp.where(is_last, outs, jnp.zeros_like(outs)).astype(
                jnp.float32), "pod").astype(outs.dtype)
        new_caches = [jax.tree.map(lambda x: x[None], c) for c in new_caches]
        return outs, new_caches

    P = jax.sharding.PartitionSpec
    in_specs = (
        [jax.tree.map(lambda _: P("pod"), p) for p in stage_params],
        [jax.tree.map(lambda _: P("pod"), c) for c in stage_caches],
        P(), P(),
    )
    out_specs = (P(), [jax.tree.map(lambda _: P("pod"), c)
                       for c in stage_caches])
    fn = _shard_map(body, mesh=_ambient_mesh(), axis_names={"pod"},
                    in_specs=in_specs, out_specs=out_specs)
    return fn(stage_params, stage_caches, queue, positions_q)


def _ambient_mesh():
    """Resolve the mesh from either the ``with mesh:`` legacy context or the
    ``jax.set_mesh`` context."""
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    if not m.empty:
        return m
    am = jax.sharding.get_abstract_mesh()
    if am is not None and not am.empty:
        return am
    raise RuntimeError("pipeline_* must run inside a mesh context "
                       "(`with mesh:` or `jax.set_mesh(mesh)`)")


# ---------------------------------------------------------------------------
# Serving entry points (pipelined)
# ---------------------------------------------------------------------------


def _validate_tick_args(name: str, *, mesh, n_stages: int,
                        checks: dict) -> None:
    """Trace-time argument validation for the persistent tick functions.

    They run under one outer ``jax.jit``, so a mis-shaped argument
    otherwise surfaces ticks later as a cryptic shard_map/scan error —
    or not at all, as a silent per-call retrace when a host integer
    leaks into a shape.  Runs only at trace time (shapes are static),
    so steady-state ticks pay nothing.  ``checks`` maps argument name
    to ``(got_shape, want_shape)``."""
    pod = dict(mesh.shape).get("pod")
    if pod != n_stages:
        raise ValueError(
            f"{name}: mesh 'pod' axis has {pod} device(s) but "
            f"n_stages={n_stages} — the pipe needs one stage per pod "
            "device")
    for arg, (got, want) in checks.items():
        if tuple(got) != tuple(want):
            raise ValueError(
                f"{name}: {arg} has shape {tuple(got)}, want "
                f"{tuple(want)} — the backend and the tick disagree on "
                "the pipe geometry")


def _epilogue(params, epi_scan_params, x, cfg, rt, *, mode, caches,
              positions):
    """Leftover periods + pattern tail + final norm (replicated over pods)."""
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    new_epi = caches["epi_scan"] if caches is not None else None
    if epi_scan_params:
        x, new_epi = model_lib.run_periods(
            epi_scan_params, x, cfg, rt, period_kinds=plan.period_kinds,
            mode=mode, scan_caches=new_epi, positions=positions)
    new_tail = []
    for i, kind in enumerate(plan.tail_kinds):
        c = caches["tail"][i] if caches is not None else None
        x, nc = model_lib.apply_layer(kind, params["tail"][i], x, cfg, rt,
                                      positions=positions, mode=mode, cache=c)
        new_tail.append(nc)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, new_epi, new_tail


def pipeline_decode_step(params, tokens, caches, cur_pos, cfg: ModelConfig,
                         rt: Runtime, pcfg: PipelineConfig):
    """One pipelined decode token for every microbatch.

    tokens (n_mb, mb) int32; cur_pos (n_mb, mb) int32 absolute positions.
    Returns (logits (n_mb, mb, V) f32, new_caches).
    """
    n_mb, mb = tokens.shape
    cd = rt.compute_dtype
    x = embed_lib.embed_tokens(params["embed"], tokens.reshape(-1), cfg, cd)
    queue = x.reshape(n_mb, mb, 1, cfg.d_model)
    positions_q = cur_pos[..., None]                       # (n_mb, mb, 1)
    if cfg.frontend == "vision_patches":
        positions_q = jnp.broadcast_to(positions_q[:, None],
                                       (n_mb, 3, mb, 1))

    stage_params, epi_scan_params = split_scan_params(params, cfg,
                                                      pcfg.n_stages)
    drained, new_stage = _pipeline_pass(
        stage_params, caches["stage"], queue, positions_q, cfg, rt, pcfg,
        mode="decode")

    xf = drained.reshape(pcfg.global_batch, 1, cfg.d_model)
    pos_flat = cur_pos.reshape(pcfg.global_batch)[:, None]
    if cfg.frontend == "vision_patches":
        from repro.models.common import text_positions3
        pos_flat = text_positions3(pos_flat)
    xf, new_epi, new_tail = _epilogue(params, epi_scan_params, xf, cfg, rt,
                                      mode="decode", caches=caches,
                                      positions=pos_flat)
    logits = embed_lib.unembed(params["embed"], xf[:, 0], cfg)
    new_caches = {"stage": new_stage, "epi_scan": new_epi, "tail": new_tail}
    return logits.reshape(n_mb, mb, -1), new_caches


def pipeline_prefill(params, inputs, caches, cfg: ModelConfig, rt: Runtime,
                     pcfg: PipelineConfig):
    """Pipelined prefill.

    ``inputs``: {"tokens": (n_mb, mb, S)} — or the stub-frontend forms
    {"frames": (n_mb, mb, S, D)} / {"tokens", "patches"} (vlm), all with the
    (n_mb, mb) microbatch layout on the leading dims.
    Returns (last_logits (n_mb, mb, V) f32, new_caches)."""
    if isinstance(inputs, jax.Array):
        inputs = {"tokens": inputs}
    n_mb, mb = next(iter(inputs.values())).shape[:2]
    flat = {k: v.reshape((n_mb * mb,) + v.shape[2:])
            for k, v in inputs.items()}
    x, positions = model_lib.embed_inputs(params, flat, cfg, rt,
                                          mode="prefill")
    S = x.shape[1]
    queue = x.reshape(n_mb, mb, S, cfg.d_model)
    if positions.ndim == 3:          # (3, B, S) m-rope
        positions_q = positions.reshape(3, n_mb, mb, S).transpose(1, 0, 2, 3)
        pos = positions[0].reshape(n_mb, mb, S)
    else:
        pos = positions.reshape(n_mb, mb, S)
        positions_q = pos

    stage_params, epi_scan_params = split_scan_params(params, cfg,
                                                      pcfg.n_stages)
    drained, new_stage = _pipeline_pass(
        stage_params, caches["stage"], queue, positions_q, cfg, rt, pcfg,
        mode="prefill")

    xf = drained.reshape(pcfg.global_batch, S, cfg.d_model)
    pos_flat = positions          # embed_inputs layout: (B, S) or (3, B, S)
    xf, new_epi, new_tail = _epilogue(params, epi_scan_params, xf, cfg, rt,
                                      mode="prefill", caches=caches,
                                      positions=pos_flat)
    logits = embed_lib.unembed(params["embed"], xf[:, -1], cfg)
    new_caches = {"stage": new_stage, "epi_scan": new_epi, "tail": new_tail}
    return logits.reshape(n_mb, mb, -1), new_caches


# ---------------------------------------------------------------------------
# Single-tick circular decode over ENGINE-format paged caches
# ---------------------------------------------------------------------------
#
# The serving engine's PipelinedBackend keeps the §4.3 circular schedule
# *persistent*: each engine tick injects one microbatch at stage 0 and
# advances every in-flight microbatch one stage.  Unlike the fixed-batch
# passes above (which own stage-major dense caches), this path runs over
# the engine's canonical paged-cache pytree — scan leaves (n_periods, ...)
# are split into per-stage slices inside the jit, so continuous batching,
# page tables, and the double-buffer offloader keep operating on the one
# host-side layout.


def pipeline_decode_tick(params, caches, act, tokens, mb_assign, pos_stage,
                         samp_keys, samp_steps, samp_temp, samp_top_k,
                         samp_top_p, drop_stage, *, cfg: ModelConfig,
                         rt: Runtime, n_stages: int, mb_size: int, mesh,
                         wire_dtype: str = "fp32",
                         sample_fast_path: bool = True):
    """Advance the persistent pipeline by one tick.

    caches:    engine-format paged caches ({"scan": [...], "tail": [...]}).
    act:       (n_stages, mb_size, 1, D) input activation per stage; row 0
               is replaced by the embedded ``tokens`` (the injection).
    tokens:    (mb_size,) int32 — last tokens of the injected microbatch.
    mb_assign: (n_stages,) int32 — microbatch id each stage processes this
               tick (-1 = bubble).  ``mb_assign[-1]`` is the draining one.
    pos_stage: (n_stages, mb_size) int32 absolute positions per stage.
    samp_*:    per-row sampling state of the *draining* microbatch —
               ``samp_keys`` (mb_size, 2) uint32 base keys, ``samp_steps``
               (mb_size,) token indices, temperature / top-k / top-p
               (mb_size,) — captured at its injection, so every request
               is sampled under its own params regardless of pipe depth.
    drop_stage: () int32 fault-injection seam — the stage whose tick is
               *lost* this tick (-1 = none).  Its microbatch's cache
               writes are masked exactly like a bubble's and, when it is
               the draining stage, the drained result is invalid: the
               caller must treat the microbatch as a lost tick and
               re-inject it (decode writes are position-keyed, so the
               retry rewrites identical KV — see serving/engine.py).
    wire_dtype: static wire codec for the inter-stage ppermute payload —
               "fp32" (identity, bit-identical) or "int8" (per-row
               quantize → permute → dequantize; see ``_wire_permute``).

    Returns (sampled tokens (mb_size,), model logprobs (mb_size,) for the
    draining microbatch — garbage when ``mb_assign[-1] < 0`` or the last
    stage was dropped —, new caches, new act).
    """
    from repro.serving import kv_cache as kvc
    from repro.serving.sampler import (fold_in_steps, sample_batched,
                                       token_logprobs)

    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    pps, leftover = split_layers(cfg, n_stages)
    n_scan = pps * n_stages
    cd = rt.compute_dtype
    _validate_tick_args(
        "pipeline_decode_tick", mesh=mesh, n_stages=n_stages, checks={
            "act": (act.shape, (n_stages, mb_size, 1, cfg.d_model)),
            "tokens": (tokens.shape, (mb_size,)),
            "mb_assign": (mb_assign.shape, (n_stages,)),
            "pos_stage": (pos_stage.shape, (n_stages, mb_size)),
            "samp_keys": (samp_keys.shape, (mb_size, 2)),
            "samp_steps": (samp_steps.shape, (mb_size,)),
        })

    stage_params, epi_scan_params = split_scan_params(params, cfg, n_stages)
    stage_caches = [jax.tree.map(
        lambda x: x[:n_scan].reshape((n_stages, pps) + x.shape[1:]), c)
        for c in caches["scan"]]
    epi_scan_caches = [jax.tree.map(lambda x: x[n_scan:], c)
                       for c in caches["scan"]] if leftover else []

    x_inj = embed_lib.embed_tokens(params["embed"], tokens, cfg, cd)[:, None]

    def body(stage_params_l, stage_caches_l, act_l, x_inj, mb_assign,
             pos_stage, drop_stage):
        lp = [jax.tree.map(lambda x: x[0], p) for p in stage_params_l]
        lc = [jax.tree.map(lambda x: x[0], c) for c in stage_caches_l]
        pod = jax.lax.axis_index("pod")
        is_last = pod == n_stages - 1

        x_in = jnp.where(pod == 0, x_inj, act_l[0])
        mb_id = jax.lax.dynamic_index_in_dim(mb_assign, pod, 0,
                                             keepdims=False)
        active = (mb_id >= 0) & (pod != drop_stage)
        row0 = jnp.maximum(mb_id, 0) * mb_size
        pos = jax.lax.dynamic_index_in_dim(pos_stage, pod, 0,
                                           keepdims=False)
        p1 = pos[:, None]
        if cfg.frontend == "vision_patches":
            from repro.models.common import text_positions3
            p1 = text_positions3(p1)

        # per-microbatch row views of this stage's period slice (pools
        # shared, per-slot leaves row-sliced at axis 1 after the period
        # axis — same convention the single-device backend uses)
        view = []
        for c in lc:
            shared, per = kvc._split_shared(c)
            per = jax.tree.map(lambda x: jax.lax.dynamic_slice_in_dim(
                x, row0, mb_size, axis=1), per)
            view.append({**shared, **per})
        y, new_view = model_lib.run_periods(
            lp, x_in, cfg, rt, period_kinds=plan.period_kinds,
            mode="decode", scan_caches=view, positions=p1)

        new_lc = []
        for c_old, v_old, v_new in zip(lc, view, new_view):
            v_new = jax.tree.map(lambda n, o: jnp.where(active, n, o),
                                 v_new, v_old)               # mask bubbles
            merged = {}
            for k in c_old:
                if k.endswith("_pages"):
                    merged[k] = v_new[k].astype(c_old[k].dtype)
                else:
                    merged[k] = jax.lax.dynamic_update_slice_in_dim(
                        c_old[k], v_new[k].astype(c_old[k].dtype), row0,
                        axis=1)
            new_lc.append(merged)

        # drained activation: the last stage's output, broadcast to all
        # pods (f32 psum: see the note in _pipeline_pass)
        y_out = jax.lax.psum(
            jnp.where(is_last, y, jnp.zeros_like(y)).astype(jnp.float32),
            "pod").astype(y.dtype)
        # ship activations one stage downstream for the next tick
        y_next = _wire_permute(y, n_stages, wire_dtype)
        new_lc = [jax.tree.map(lambda x: x[None], c) for c in new_lc]
        return y_out, y_next[None], new_lc

    P = jax.sharding.PartitionSpec
    in_specs = (
        [jax.tree.map(lambda _: P("pod"), p) for p in stage_params],
        [jax.tree.map(lambda _: P("pod"), c) for c in stage_caches],
        P("pod"), P(), P(), P(), P(),
    )
    out_specs = (P(), P("pod"),
                 [jax.tree.map(lambda _: P("pod"), c) for c in stage_caches])
    fn = _shard_map(body, mesh=mesh, axis_names={"pod"},
                    in_specs=in_specs, out_specs=out_specs)
    y_out, new_act, new_stage = fn(stage_params, stage_caches, act, x_inj,
                                   mb_assign, pos_stage,
                                   jnp.asarray(drop_stage, jnp.int32))

    # epilogue + sampling for the draining microbatch (replicated — this is
    # the paper's return link: (mb,) token ids per tick, not activations)
    out_mb = mb_assign[n_stages - 1]
    valid = (out_mb >= 0) & (jnp.asarray(drop_stage) != n_stages - 1)
    row0 = jnp.maximum(out_mb, 0) * mb_size
    pos_d = pos_stage[n_stages - 1]
    p1 = pos_d[:, None]
    if cfg.frontend == "vision_patches":
        from repro.models.common import text_positions3
        p1 = text_positions3(p1)
    epi_full = {"scan": epi_scan_caches, "tail": caches["tail"]}
    epi_view = kvc.slot_view(epi_full, row0, mb_size)
    xf, new_epi_scan, new_tail = _epilogue(
        params, epi_scan_params, y_out, cfg, rt, mode="decode",
        caches={"epi_scan": epi_view["scan"], "tail": epi_view["tail"]},
        positions=p1)
    logits = embed_lib.unembed(params["embed"], xf[:, 0], cfg)
    toks = sample_batched(logits, fold_in_steps(samp_keys, samp_steps),
                          samp_temp, samp_top_k, samp_top_p,
                          fast_path=sample_fast_path)
    lps = token_logprobs(logits, toks)

    new_epi_view = {"scan": new_epi_scan or [], "tail": new_tail}
    new_epi_view = jax.tree.map(lambda n, o: jnp.where(valid, n, o),
                                new_epi_view, epi_view)      # mask bubbles
    epi_merged = kvc.slot_merge(epi_full, new_epi_view, row0)

    new_scan = []
    for i in range(len(caches["scan"])):
        st = jax.tree.map(lambda x: x.reshape((n_scan,) + x.shape[2:]),
                          new_stage[i])
        if leftover:
            st = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                              st, epi_merged["scan"][i])
        new_scan.append(st)
    new_caches = {"scan": new_scan, "tail": epi_merged["tail"]}
    return toks, lps, new_caches, new_act


# ---------------------------------------------------------------------------
# Single-tick chunked prefill over ENGINE-format paged caches
# ---------------------------------------------------------------------------


def pipeline_prefill_chunk_tick(params, caches, act, tokens, offs_stage,
                                valid_stage, tables_stage, lasts,
                                drop_stage, *, cfg: ModelConfig, rt: Runtime,
                                n_stages: int, mesh,
                                wire_dtype: str = "fp32"):
    """Advance the persistent *prefill* pipe by one tick.

    The serving engine's ``PipelinedBackend`` keeps a second shift register
    for prompt chunks: each engine tick injects (at most) one chunk at
    stage 0 and advances every in-flight chunk one stage, exactly like
    ``pipeline_decode_tick`` — so a prefill chunk overlaps the in-flight
    decode microbatches instead of pausing them.

    caches:       engine-format paged caches (every layer paged — the
                  engine gates ring/recurrent archs to exact prefill).
    act:          (n_stages, R, C, D) chunk activation per stage input.
    tokens:       (R, C) int32 — the chunk injected at stage 0 this tick.
    offs_stage:   (n_stages, R) int32 prefilled-token offsets per stage.
    valid_stage:  (n_stages, R) int32 real-token counts (0 = bubble row or
                  bubble stage — every cache write is dropped).
    tables_stage: (n_stages, R, P) int32 per-row page-table rows (the
                  device-wide table keeps prefilling slots parked).
    lasts:        (R,) int32 within-chunk final-token index of the
                  *draining* chunk.
    drop_stage:   () int32 fault-injection seam: the stage whose tick is
                  lost this tick (-1 = none).  Its chunk's valid counts
                  are zeroed, so every cache write at that stage is
                  dropped; the caller re-injects the lost chunk (prompt-KV
                  writes are offset-keyed, so the retry rewrites identical
                  pages — see serving/engine.py).

    Returns (logits (R, V) for the draining chunk — garbage when no chunk
    drains or the last stage was dropped —, new caches, new act).
    """
    pps, leftover = split_layers(cfg, n_stages)
    n_scan = pps * n_stages
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    cd = rt.compute_dtype
    R, C = tokens.shape
    _validate_tick_args(
        "pipeline_prefill_chunk_tick", mesh=mesh, n_stages=n_stages,
        checks={
            "act": (act.shape, (n_stages, R, C, cfg.d_model)),
            "offs_stage": (offs_stage.shape, (n_stages, R)),
            "valid_stage": (valid_stage.shape, (n_stages, R)),
            "tables_stage": (tables_stage.shape[:2], (n_stages, R)),
            "lasts": (lasts.shape, (R,)),
        })
    # the fault seam: a dropped stage becomes a bubble stage — n_valid 0
    # masks every one of its cache writes through the chunk recurrences
    valid_stage = jnp.where(
        jnp.arange(n_stages)[:, None] == jnp.asarray(drop_stage), 0,
        valid_stage)

    stage_params, epi_scan_params = split_scan_params(params, cfg, n_stages)
    stage_caches = [jax.tree.map(
        lambda x: x[:n_scan].reshape((n_stages, pps) + x.shape[1:]), c)
        for c in caches["scan"]]
    epi_scan_caches = [jax.tree.map(lambda x: x[n_scan:], c)
                       for c in caches["scan"]] if leftover else []

    x_inj = embed_lib.embed_tokens(params["embed"], tokens, cfg, cd)

    def chunk_positions(offs, nv):
        iota = jnp.arange(C)[None]
        pos = jnp.where(iota < nv[:, None], offs[:, None] + iota, -1)
        if cfg.frontend == "vision_patches":
            from repro.models.common import text_positions3
            return pos, text_positions3(pos)
        return pos, pos

    def body(stage_params_l, stage_caches_l, act_l, x_inj, offs_stage,
             valid_stage, tables_stage):
        lp = [jax.tree.map(lambda x: x[0], p) for p in stage_params_l]
        lc = [jax.tree.map(lambda x: x[0], c) for c in stage_caches_l]
        pod = jax.lax.axis_index("pod")
        is_last = pod == n_stages - 1

        x_in = jnp.where(pod == 0, x_inj, act_l[0])
        offs = jax.lax.dynamic_index_in_dim(offs_stage, pod, 0,
                                            keepdims=False)
        nv = jax.lax.dynamic_index_in_dim(valid_stage, pod, 0,
                                          keepdims=False)
        tabs = jax.lax.dynamic_index_in_dim(tables_stage, pod, 0,
                                            keepdims=False)     # (R, P)
        _, p1 = chunk_positions(offs, nv)

        # the chunk's rows are arbitrary slots: run the stage's period
        # slice with the chunk's own page-table rows; pools are shared,
        # the parked per-slot table leaves pass through untouched
        view = [{**c, "page_table": jnp.broadcast_to(
            tabs[None], (pps,) + tabs.shape)} for c in lc]
        y, new_view = model_lib.run_periods(
            lp, x_in, cfg, rt, period_kinds=plan.period_kinds,
            mode="chunk", scan_caches=view, positions=p1)
        new_lc = [{**{k: v.astype(c_old[k].dtype)
                      for k, v in v_new.items() if k.endswith("_pages")},
                   "page_table": c_old["page_table"]}
                  for c_old, v_new in zip(lc, new_view)]

        y_out = jax.lax.psum(
            jnp.where(is_last, y, jnp.zeros_like(y)).astype(jnp.float32),
            "pod").astype(y.dtype)
        y_next = _wire_permute(y, n_stages, wire_dtype)
        new_lc = [jax.tree.map(lambda x: x[None], c) for c in new_lc]
        return y_out, y_next[None], new_lc

    P = jax.sharding.PartitionSpec
    in_specs = (
        [jax.tree.map(lambda _: P("pod"), p) for p in stage_params],
        [jax.tree.map(lambda _: P("pod"), c) for c in stage_caches],
        P("pod"), P(), P(), P(), P(),
    )
    out_specs = (P(), P("pod"),
                 [jax.tree.map(lambda _: P("pod"), c) for c in stage_caches])
    fn = _shard_map(body, mesh=mesh, axis_names={"pod"},
                    in_specs=in_specs, out_specs=out_specs)
    y_out, new_act, new_stage = fn(stage_params, stage_caches, act, x_inj,
                                   offs_stage, valid_stage, tables_stage)

    # epilogue for the draining chunk (replicated; the paper's return link
    # carries (R,) first-token logit rows once per chunk, not activations)
    offs_d = offs_stage[n_stages - 1]
    nv_d = valid_stage[n_stages - 1]
    tabs_d = tables_stage[n_stages - 1]
    pos_d, p1 = chunk_positions(offs_d, nv_d)
    epi_view = {
        "epi_scan": [{**c, "page_table": jnp.broadcast_to(
            tabs_d[None], (c["page_table"].shape[0],) + tabs_d.shape)}
            for c in epi_scan_caches],
        "tail": [{**c, "page_table": tabs_d} for c in caches["tail"]],
    }
    xf, new_epi_scan, new_tail = _epilogue(
        params, epi_scan_params, y_out, cfg, rt, mode="chunk",
        caches=epi_view, positions=p1)
    idx = jnp.clip(lasts, 0, C - 1).reshape(R, 1, 1)
    x_last = jnp.take_along_axis(
        xf, jnp.broadcast_to(idx, (R, 1, xf.shape[-1])), axis=1)[:, 0]
    logits = embed_lib.unembed(params["embed"], x_last, cfg)

    keep = lambda n, o: {**{k: v.astype(o[k].dtype) for k, v in n.items()
                            if k.endswith("_pages")},
                         "page_table": o["page_table"]}
    epi_merged_scan = [keep(n, o) for n, o in
                       zip(new_epi_scan or [], epi_scan_caches)]
    new_tail = [keep(n, o) for n, o in zip(new_tail, caches["tail"])]

    new_scan = []
    for i in range(len(caches["scan"])):
        st = jax.tree.map(lambda x: x.reshape((n_scan,) + x.shape[2:]),
                          new_stage[i])
        if leftover:
            st = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=0),
                              st, epi_merged_scan[i])
        new_scan.append(st)
    new_caches = {"scan": new_scan, "tail": new_tail}
    return logits, new_caches, new_act


# ---------------------------------------------------------------------------
# Multi-round circular decode (the §4.3 steady state, compiled)
# ---------------------------------------------------------------------------


def pipeline_decode_rounds(params, tokens, caches, cur_pos,
                           cfg: ModelConfig, rt: Runtime,
                           pcfg: PipelineConfig, *, rounds: int):
    """Greedy-decode ``rounds`` tokens per microbatch in ONE circular pass.

    This is the schedule the paper actually runs in steady state: microbatch
    ``m``'s round-``r`` token is injected at tick ``r·N_B + m``, immediately
    behind its round-``r−1`` drain (legal because N_B ≥ N_S) — fill/drain
    bubbles amortise to (N_S−1)/(R·N_B + N_S − 1).  Sampling (greedy) and
    re-embedding happen replicated across pods on the drained activations;
    the paper's return link carries the (mb,) token ids.

    tokens/cur_pos (n_mb, mb) int32.  Returns (all_tokens (rounds, n_mb,
    mb) int32, new_caches).  Requires N_B ≥ N_S.
    """
    n_s, n_mb, mb = pcfg.n_stages, pcfg.n_microbatches, pcfg.mb_size
    if n_mb < n_s:
        raise ValueError("multi-round circular decode needs N_B >= N_S")
    pps, _ = split_layers(cfg, n_s)
    plan = make_layer_plan(cfg.num_layers, cfg.block_pattern)
    cd = rt.compute_dtype
    n_ticks = rounds * n_mb + n_s - 1

    stage_params, epi_scan_params = split_scan_params(params, cfg, n_s)
    epi_state = {"epi_scan": caches["epi_scan"], "tail": caches["tail"]}

    def body(local_params, local_caches, epi_caches, tokens, cur_pos):
        local_params = [jax.tree.map(lambda x: x[0], p) for p in local_params]
        local_caches = [jax.tree.map(lambda x: x[0], c) for c in local_caches]
        pod = jax.lax.axis_index("pod")
        is_last = pod == n_s - 1

        def embed_mb(tok, pos):
            x = embed_lib.embed_tokens(params["embed"], tok, cfg, cd)
            return x[:, None]                       # (mb, 1, D)

        def _epi_take(epi, start):
            """Per-microbatch view of the (global-batch) epilogue caches."""
            return {
                "epi_scan": [jax.tree.map(
                    lambda l: jax.lax.dynamic_slice_in_dim(l, start, mb, 1),
                    c) for c in epi["epi_scan"]],
                "tail": [jax.tree.map(
                    lambda l: jax.lax.dynamic_slice_in_dim(l, start, mb, 0),
                    c) for c in epi["tail"]],
            }

        def _epi_put(epi, view, start):
            return {
                "epi_scan": [jax.tree.map(
                    lambda f, pth: jax.lax.dynamic_update_slice_in_dim(
                        f, pth.astype(f.dtype), start, 1), c_f, c_v)
                    for c_f, c_v in zip(epi["epi_scan"], view["epi_scan"])],
                "tail": [jax.tree.map(
                    lambda f, pth: jax.lax.dynamic_update_slice_in_dim(
                        f, pth.astype(f.dtype), start, 0), c_f, c_v)
                    for c_f, c_v in zip(epi["tail"], view["tail"])],
            }

        def epilogue_sample(y, pos, epi, out_mb):
            xf = y                                   # (mb, 1, D)
            p1 = pos[:, None]
            if cfg.frontend == "vision_patches":
                from repro.models.common import text_positions3
                p1 = text_positions3(p1)
            start = out_mb * mb
            view = _epi_take(epi, start)
            xf, new_epi, new_tail = _epilogue(
                params, epi_scan_params, xf, cfg, rt, mode="decode",
                caches=view, positions=p1)
            logits = embed_lib.unembed(params["embed"], xf[:, 0], cfg)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, _epi_put(epi, {"epi_scan": new_epi,
                                       "tail": new_tail}, start)

        def tick(carry, t):
            x, st_caches, epi, toks, pos, outs = carry
            mb_id = (t - pod) % n_mb
            rnd = (t - pod) // n_mb
            active = ((t - pod) >= 0) & (rnd < rounds)
            mb_c = jnp.clip(mb_id, 0, n_mb - 1)

            mb_caches = [jax.tree.map(
                lambda l: jax.lax.dynamic_index_in_dim(l, mb_c, 0,
                                                       keepdims=False), c)
                for c in st_caches]
            pos_mb = jax.lax.dynamic_index_in_dim(pos, mb_c, 0,
                                                  keepdims=False)
            p1 = pos_mb[:, None]
            if cfg.frontend == "vision_patches":
                from repro.models.common import text_positions3
                p1 = text_positions3(p1)
            y, new_mb = model_lib.run_periods(
                local_params, x, cfg, rt, period_kinds=plan.period_kinds,
                mode="decode", scan_caches=mb_caches, positions=p1)
            new_st = []
            for c_all, c_old, c_new in zip(st_caches, mb_caches, new_mb):
                c_new = jax.tree.map(lambda n, o: jnp.where(active, n, o),
                                     c_new, c_old)
                new_st.append(jax.tree.map(
                    lambda l, n: jax.lax.dynamic_update_index_in_dim(
                        l, n.astype(l.dtype), mb_c, 0), c_all, c_new))

            # drain: the last pod finishes microbatch (t-(n_s-1)) % n_mb;
            # broadcast its activation, run the epilogue + greedy sampling
            # replicated, append the token behind the pipe for next round
            out_id = t - (n_s - 1)
            out_mb = jnp.clip(out_id % n_mb, 0, n_mb - 1)
            out_rnd = out_id // n_mb
            out_valid = (out_id >= 0) & (out_rnd < rounds)
            y_b = jax.lax.psum(
                jnp.where(is_last, y, jnp.zeros_like(y)).astype(jnp.float32),
                "pod").astype(y.dtype)
            pos_out = jax.lax.dynamic_index_in_dim(pos, out_mb, 0,
                                                   keepdims=False)
            nxt, new_epi = epilogue_sample(y_b, pos_out, epi, out_mb)
            epi = jax.tree.map(lambda n, o: jnp.where(out_valid, n, o),
                               new_epi, epi)
            toks = jnp.where(out_valid,
                             toks.at[out_mb].set(nxt), toks)
            pos = jnp.where(out_valid, pos.at[out_mb].add(1), pos)
            outs = jnp.where(
                out_valid,
                jax.lax.dynamic_update_index_in_dim(
                    outs, jax.lax.dynamic_update_index_in_dim(
                        jax.lax.dynamic_index_in_dim(
                            outs, jnp.clip(out_rnd, 0, rounds - 1), 0,
                            keepdims=False),
                        nxt, out_mb, 0),
                    jnp.clip(out_rnd, 0, rounds - 1), 0),
                outs)

            # ship downstream; pod 0 injects the next tick's token
            y_next = jax.lax.ppermute(
                y, "pod", [(i, (i + 1) % n_s) for i in range(n_s)])
            nxt_mb = jnp.clip((t + 1) % n_mb, 0, n_mb - 1)
            inj_tok = jax.lax.dynamic_index_in_dim(toks, nxt_mb, 0,
                                                   keepdims=False)
            inj_pos = jax.lax.dynamic_index_in_dim(pos, nxt_mb, 0,
                                                   keepdims=False)
            inj = embed_mb(inj_tok, inj_pos)
            x_next = jnp.where(pod == 0, inj, y_next)
            return (x_next, new_st, epi, toks, pos, outs), None

        x0 = embed_mb(tokens[0], cur_pos[0]) * jnp.where(
            pod == 0, 1.0, 0.0).astype(cd)
        outs0 = jnp.zeros((rounds, n_mb, mb), jnp.int32)
        (x, st, epi, toks, pos, outs), _ = jax.lax.scan(
            tick, (x0, local_caches, epi_caches, tokens, cur_pos, outs0),
            jnp.arange(n_ticks))
        st = [jax.tree.map(lambda x: x[None], c) for c in st]
        return outs, st, epi

    P = jax.sharding.PartitionSpec
    in_specs = (
        [jax.tree.map(lambda _: P("pod"), p) for p in stage_params],
        [jax.tree.map(lambda _: P("pod"), c) for c in caches["stage"]],
        jax.tree.map(lambda _: P(), epi_state),
        P(), P(),
    )
    out_specs = (P(),
                 [jax.tree.map(lambda _: P("pod"), c)
                  for c in caches["stage"]],
                 jax.tree.map(lambda _: P(), epi_state))
    fn = _shard_map(body, mesh=_ambient_mesh(), axis_names={"pod"},
                    in_specs=in_specs, out_specs=out_specs)
    outs, new_stage, new_epi = fn(stage_params, caches["stage"], epi_state,
                                  tokens, cur_pos)
    new_caches = {"stage": new_stage, "epi_scan": new_epi["epi_scan"],
                  "tail": new_epi["tail"]}
    return outs, new_caches
