"""Microbatch scheduling (DeServe §4.3): fill network-latency bubbles.

With ``N_M`` pipeline stages of compute time ``T_S`` each and one-way link
latency ``L``, a microbatch's round-trip through the ring takes
``N_M · (T_S + L)``.  A stage is bubble-free iff a new microbatch arrives
every ``T_S``, i.e. iff

      N_B* = ceil( N_M · (T_S + L) / T_S )

microbatches are in flight (paper Figure 2(c): N_M=4, L=T_S/2 → N_B*=6).
The scheduler also composes the per-microbatch batch under the Formula-1
capacity, and emits the steady-state (tick, stage) → microbatch timetable
the simulator and the SPMD pipeline share.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.core import offload as offload_lib


def _lat_sum(n_stages: int, latency: float,
             link_latencies: Optional[Sequence[float]]) -> float:
    """Total one-way link latency around the ring.

    The per-link generalisation of the §4.3 formulas: a microbatch's
    round trip is ``n_stages·T_S + Σ L_i`` — only the *sum* of the ring
    latencies enters the steady state (the ``PipelineSimulator``'s
    circular round time uses exactly this) — which collapses to
    ``n_stages·(T_S+L)`` on a uniform ring.  ``link_latencies`` wins
    when both are given (the scalar stays as the display/back-compat
    argument)."""
    if link_latencies is not None:
        lats = [float(l) for l in link_latencies]
        if len(lats) != n_stages:
            raise ValueError(
                f"link_latencies has {len(lats)} entries but the ring has "
                f"{n_stages} stage(s) — one link per stage")
        if any(l < 0 for l in lats):
            raise ValueError(f"link latencies must be >= 0, got {lats}")
        return sum(lats)
    return n_stages * latency


def optimal_microbatches(n_stages: int, stage_time: float,
                         latency: float = 0.0, *,
                         link_latencies: Optional[Sequence[float]] = None
                         ) -> int:
    """N_B* — the bubble-free in-flight microbatch count (paper §4.3).

    Per-link form: ``ceil((N_M·T_S + Σ L_i) / T_S)``; the uniform-ring
    scalar ``latency`` reproduces the paper's ``N_M·(T_S+L)/T_S``."""
    if stage_time <= 0:
        return n_stages
    trip = n_stages * stage_time + _lat_sum(n_stages, latency,
                                            link_latencies)
    return max(n_stages, math.ceil(trip / stage_time))


def bubble_fraction(n_stages: int, n_microbatches: int, stage_time: float,
                    latency: float = 0.0, *,
                    link_latencies: Optional[Sequence[float]] = None
                    ) -> float:
    """Fraction of each stage's steady-state time spent idle.

    A microbatch returns to a stage after ``N_M·T_S + Σ L_i``; the stage
    does useful work for ``N_B·T_S`` of that (capped at 1.0)."""
    period = n_stages * stage_time + _lat_sum(n_stages, latency,
                                              link_latencies)
    busy = min(n_microbatches * stage_time, period)
    return max(0.0, 1.0 - busy / period)


@dataclass(frozen=True)
class PipelineSchedule:
    n_stages: int
    n_microbatches: int
    stage_time: float
    latency: float

    @property
    def round_trip(self) -> float:
        return self.n_stages * (self.stage_time + self.latency)

    @property
    def steady_tick(self) -> float:
        """Wall time between consecutive ticks of one stage in steady state:
        max of compute-bound (T_S) and latency-bound (round-trip / N_B)."""
        return max(self.stage_time, self.round_trip / self.n_microbatches)

    def microbatch_at(self, stage: int, tick: int) -> int:
        """Steady-state circular schedule: stage s processes microbatch
        (tick - s) mod N_B at tick ``tick``."""
        return (tick - stage) % self.n_microbatches

    def utilisation(self) -> float:
        return 1.0 - bubble_fraction(self.n_stages, self.n_microbatches,
                                     self.stage_time, self.latency)


@dataclass
class ScheduleChoice:
    """Output of the planner: how many microbatches, how large each batch."""
    n_microbatches: int
    per_mb_batch: int
    per_mb_kv_bytes: float
    utilisation: float
    offload: bool

    @property
    def total_batch(self) -> int:
        return self.n_microbatches * self.per_mb_batch


def plan_schedule(*, n_stages: int, stage_time: float, latency: float = 0.0,
                  link_latencies: Optional[Sequence[float]] = None,
                  m_kv_bytes: float, kv_bytes_per_seq: float,
                  offload_bandwidth: float = offload_lib.TPU_HOST_DMA_BW,
                  use_offload: bool = True,
                  host_kv_bytes: float = float("inf"),
                  max_microbatches: int = 64) -> ScheduleChoice:
    """Choose (N_B, per-microbatch batch) maximising steady-state throughput.

    Steady-state output rate is  N_B·b / max(N_B·T_S, N_M·T_S + Σ L_i) —
    flat in N_B once the pipe is bubble-free, so the planner picks the
    *smallest* N_B attaining the maximum (less host memory, less in-flight
    state).  ``link_latencies`` is the per-link generalisation (a real
    deployment's heterogeneous ring — ``DeploymentPlan.link_latencies``
    plugs straight in); the scalar ``latency`` is the uniform-ring
    shorthand ``Σ L_i = N_M·L``.  Without offload, raising N_B shrinks
    per-mb capacity (wash at best); with offload the M_G floor keeps
    per-mb batch up while N_B covers the latency — the paper's central
    synergy.  ``host_kv_bytes`` bounds the total offloaded footprint
    N_B·M_B'.
    """
    best: Optional[ScheduleChoice] = None
    best_rate = -1.0
    lat_sum = _lat_sum(n_stages, latency, link_latencies)
    n_star = optimal_microbatches(n_stages, stage_time, latency,
                                  link_latencies=link_latencies)
    # search a little past N_B* but never past the hard cap: the caller's
    # host memory / pipe depth bound wins over the bubble-free optimum
    if max_microbatches < n_stages:
        raise ValueError(
            f"max_microbatches={max_microbatches} < n_stages={n_stages}: "
            "the circular schedule needs at least one microbatch per stage")
    hi = min(max(n_star + 2, n_stages), max_microbatches)
    for n_b in range(n_stages, hi + 1):
        if use_offload:
            m_g = min(offload_lib.global_pool_bytes(offload_bandwidth,
                                                    stage_time),
                      m_kv_bytes / 2.0)
            cap = offload_lib.per_microbatch_capacity(m_kv_bytes, m_g, n_b)
        else:
            cap = offload_lib.per_microbatch_capacity_no_offload(
                m_kv_bytes, n_b)
        if n_b * cap > host_kv_bytes + m_kv_bytes:
            continue
        bsz = offload_lib.batch_size_from_capacity(cap, kv_bytes_per_seq)
        if bsz == 0:
            continue
        util = 1.0 - bubble_fraction(n_stages, n_b, stage_time, latency,
                                     link_latencies=link_latencies)
        rate = (n_b * bsz) / max(n_b * stage_time,
                                 n_stages * stage_time + lat_sum)
        if rate > best_rate * (1.0 + 1e-9):
            best_rate = rate
            best = ScheduleChoice(n_microbatches=n_b, per_mb_batch=bsz,
                                  per_mb_kv_bytes=cap, utilisation=util,
                                  offload=use_offload)
    if best is None:
        raise ValueError("no feasible schedule: one sequence's KV exceeds "
                         "per-microbatch capacity")
    return best


def schedule_diagram(n_stages: int, n_microbatches: int, *,
                     stage_time: float = 1.0, latency: float = 0.0,
                     ticks: int = 0) -> str:
    """ASCII rendering of the circular schedule (paper Figure 2).

    Each cell is the microbatch a stage processes at that tick; '.' is a
    bubble (fill/drain or latency-starved).  With the N_B* count the steady
    state shows no '.' columns — the paper's Figure 2(c).
    """
    ticks = ticks or (2 * n_microbatches + n_stages)
    need = optimal_microbatches(n_stages, stage_time, latency)
    lines = [f"stages={n_stages} N_B={n_microbatches} "
             f"(bubble-free needs N_B*={need})"]
    for s in range(n_stages):
        row = []
        for t in range(ticks):
            m = t - s
            if m < 0:
                row.append(" .")
            elif n_microbatches >= need:
                row.append(f"{m % n_microbatches:2d}")
            else:
                # latency-starved: stage idles between rounds
                phase = m % need
                row.append(f"{phase:2d}" if phase < n_microbatches else " .")
        lines.append(f"  stage{s} |" + "".join(row))
    return "\n".join(lines)
