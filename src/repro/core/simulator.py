"""Discrete-event simulator of pipelined decode over high-latency links.

Reproduces the *mechanics* behind paper Table 4: three serving policies over
a ring of ``N_M`` stages with one-way link latency ``L``:

  vllm_pp      round-flushed pipelining (fill/drain every token round,
               N_B = N_M, no offload) — the vLLM-PP baseline behaviour.
  deserve_pp   circular pipelining (no flush), N_B = N_M, no offload.
  deserve_opt  circular + microbatch scheduling (N_B = N_B*(L)) + KV-cache
               offloading (per-microbatch capacity from Formula 1).

Stage compute time T_S(b) is interpolated from the paper's Table 3
batch-size→latency curve and scaled by a single calibration constant chosen
so that deserve_pp at <1 ms latency matches the paper's 194.6 tok/s
(see ``calibrate``).  All *ratios* between policies and latencies are then
produced by the simulated mechanics, not by fitting.

Workload follows §5: prompt and generation lengths ~ U[0, 512] (mean 256),
requests replenished as they finish, statistics from the post-warmup window.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core import offload as offload_lib
from repro.core import scheduler as sched_lib

# Paper Table 3: batch size -> total stage execution time (ms)
TABLE3_BATCH = [1, 2, 4, 8, 16, 32, 64, 128, 256]
TABLE3_MS = [66.6, 68.9, 69.1, 69.5, 70.3, 76.5, 80.2, 89.1, 137.5]

# Paper Table 4 reference (output tok/s) for validation in benchmarks
PAPER_TABLE4 = {
    "vllm_pp": {0.0: 89.1, 0.016: 68.8, 0.032: 55.3, 0.064: 36.1},
    "deserve_pp": {0.0: 194.6, 0.016: 182.3, 0.032: 163.7, 0.064: 133.7},
    "deserve_opt": {0.0: 445.2, 0.016: 458.5, 0.032: 457.3, 0.064: 456.8,
                    0.256: 442.9},
}


def stage_time(batch: int, scale: float = 1.0) -> float:
    """T_S(b) in seconds: log-linear interpolation of Table 3, linear
    extrapolation beyond 256."""
    if batch <= 0:
        return 0.0
    if batch >= TABLE3_BATCH[-1]:
        # linear in batch beyond the table (memory-bandwidth saturated)
        slope = (TABLE3_MS[-1] - TABLE3_MS[-2]) / (
            TABLE3_BATCH[-1] - TABLE3_BATCH[-2])
        ms = TABLE3_MS[-1] + slope * (batch - TABLE3_BATCH[-1])
        return ms * 1e-3 * scale
    i = bisect.bisect_left(TABLE3_BATCH, batch)
    if TABLE3_BATCH[i] == batch:
        return TABLE3_MS[i] * 1e-3 * scale
    b0, b1 = TABLE3_BATCH[i - 1], TABLE3_BATCH[i]
    m0, m1 = TABLE3_MS[i - 1], TABLE3_MS[i]
    f = (math.log(batch) - math.log(b0)) / (math.log(b1) - math.log(b0))
    return (m0 + f * (m1 - m0)) * 1e-3 * scale


@dataclass
class SimConfig:
    policy: str = "deserve_opt"         # vllm_pp | deserve_pp | deserve_opt
    n_stages: int = 8
    latency: float = 0.0                # one-way link latency, seconds
                                        # (uniform fast path — see
                                        # link_latencies for per-link)
    # per-link one-way latencies, one per ring link s -> (s+1) mod N_S —
    # set to cross-check heterogeneous DeploymentPlan topologies; None
    # keeps the scalar fast path (the Table 4 grid).  When set it must
    # have n_stages entries and overrides ``latency``.
    link_latencies: Optional[tuple] = None
    m_kv_bytes: float = 2.0e9           # KV memory per stage (Fig. 3 M_KV:
                                        # 24 GB − 17.5 GB weights − activations
                                        # − allocator reserve on a 4090)
    kv_bytes_per_token: float = 40960.0  # per token per stage (llama3-70b/8)
    host_kv_bytes: float = 48e9         # host DRAM available for offload
    offload_bandwidth: float = 6e9      # *effective* page-granular PCIe BW
                                        # (theoretical 24 GB/s derated for
                                        # page-sized transfers + contention;
                                        # 6 GB/s reproduces the paper's flat
                                        # DeServe(opt) ≈ 450 tok/s profile)
    time_scale: float = 1.0             # calibration constant for T_S
    mean_prompt: int = 256
    mean_gen: int = 256
    sim_seconds: float = 1200.0         # paper: 20 min
    warmup_seconds: float = 240.0       # paper: stats from last 16 min
    seed: int = 0
    max_microbatches: int = 64

    def __post_init__(self):
        if self.link_latencies is not None:
            self.link_latencies = tuple(float(l) for l in
                                        self.link_latencies)
            if len(self.link_latencies) != self.n_stages:
                raise ValueError(
                    f"link_latencies has {len(self.link_latencies)} "
                    f"entries but the ring has {self.n_stages} link(s) "
                    "(one per stage)")

    # -- per-link geometry (uniform scalar reduces to the paper's L) ------

    @property
    def lat_max(self) -> float:
        """Slowest link — what the planner's bubble budget must cover."""
        if self.link_latencies is None:
            return self.latency
        return max(self.link_latencies)

    @property
    def lat_sum(self) -> float:
        """Total link time of one ring traversal (uniform: N_S·L)."""
        if self.link_latencies is None:
            return self.n_stages * self.latency
        return sum(self.link_latencies)

    @property
    def lat_mean(self) -> float:
        """Scalar-equivalent latency for the §4.3 planner: the circular
        round trip is N_S·(T_S + lat_mean) = N_S·T_S + lat_sum."""
        return self.lat_sum / self.n_stages


@dataclass
class _Seq:
    prompt: int
    gen_target: int
    generated: int = 0

    @property
    def context(self) -> int:
        return self.prompt + self.generated


@dataclass
class SimResult:
    output_tps: float
    total_tps: float
    n_microbatches: int
    per_mb_batch: float
    utilisation: float
    round_time: float
    stage_time: float
    m_g_bytes: float


class PipelineSimulator:
    """Round-granular discrete-event simulation (one decode token per active
    sequence per round)."""

    def __init__(self, cfg: SimConfig):
        self.cfg = cfg
        self.rng = np.random.RandomState(cfg.seed)

    def _new_seq(self) -> _Seq:
        c = self.cfg
        return _Seq(prompt=int(self.rng.randint(0, 2 * c.mean_prompt + 1)),
                    gen_target=max(1, int(self.rng.randint(
                        0, 2 * c.mean_gen + 1))))

    # -- capacity / schedule -------------------------------------------------

    def _plan(self) -> sched_lib.ScheduleChoice:
        c = self.cfg
        kv_seq = (c.mean_prompt + c.mean_gen / 2) * c.kv_bytes_per_token
        if c.policy == "deserve_opt":
            # fixpoint: T_S depends on b, M_G depends on T_S
            n_b, bsz = c.n_stages, 8
            for _ in range(8):
                ts = stage_time(bsz, c.time_scale)
                choice = sched_lib.plan_schedule(
                    n_stages=c.n_stages, stage_time=ts, latency=c.lat_mean,
                    m_kv_bytes=c.m_kv_bytes, kv_bytes_per_seq=kv_seq,
                    offload_bandwidth=c.offload_bandwidth, use_offload=True,
                    host_kv_bytes=c.host_kv_bytes,
                    max_microbatches=c.max_microbatches)
                if choice.per_mb_batch == bsz and choice.n_microbatches == n_b:
                    break
                bsz, n_b = choice.per_mb_batch, choice.n_microbatches
            return choice
        # fixed N_B = N_M policies, no offload
        cap = offload_lib.per_microbatch_capacity_no_offload(
            c.m_kv_bytes, c.n_stages)
        bsz = max(1, offload_lib.batch_size_from_capacity(cap, kv_seq))
        ts = stage_time(bsz, c.time_scale)
        util = 1.0 - sched_lib.bubble_fraction(c.n_stages, c.n_stages, ts,
                                               c.lat_mean)
        return sched_lib.ScheduleChoice(
            n_microbatches=c.n_stages, per_mb_batch=bsz, per_mb_kv_bytes=cap,
            utilisation=util, offload=False)

    def _round_time(self, ts: float, n_b: int) -> float:
        c = self.cfg
        if c.policy == "vllm_pp":
            # fill/drain every token round + driver round-trip to coordinate
            # the next round (centralized scheduler, rank 0).  Per-link
            # form: one traversal pays every link once (lat_sum); the
            # (N_B − 1) pipelined follow-ups and the driver round trip are
            # paced by the slowest link.  Uniform links reduce this to the
            # paper's (N_S + N_B − 1)(T_S + L) + 2L.
            return c.n_stages * ts + c.lat_sum \
                + (n_b - 1) * (ts + c.lat_max) + 2 * c.lat_max
        # circular: bubble-free iff N_B·T_S covers the full ring traversal
        # N_S·T_S + Σ L_i (uniform: N_B >= N_M (T_S + L) / T_S)
        return max(n_b * ts, c.n_stages * ts + c.lat_sum)

    # -- main loop ------------------------------------------------------------

    def run(self) -> SimResult:
        c = self.cfg
        choice = self._plan()
        n_b = choice.n_microbatches
        cap = choice.per_mb_kv_bytes

        mbs: List[List[_Seq]] = [[] for _ in range(n_b)]
        t = 0.0
        out_tokens = 0
        in_tokens = 0
        counted_from = c.warmup_seconds
        rounds = 0
        ts_now = stage_time(max(1, choice.per_mb_batch), c.time_scale)

        def mb_kv(m: List[_Seq]) -> float:
            return sum(s.context * c.kv_bytes_per_token for s in m)

        while t < c.sim_seconds:
            # replenish every microbatch up to its KV capacity
            admitted = 0
            for m in mbs:
                while True:
                    s = self._new_seq()
                    need = (s.prompt + s.gen_target / 2) * c.kv_bytes_per_token
                    if mb_kv(m) + need > cap or len(m) >= 4096:
                        break
                    m.append(s)
                    admitted += s.prompt
            batch = max(1, max(len(m) for m in mbs))
            ts_now = stage_time(batch, c.time_scale)
            rt = self._round_time(ts_now, n_b)
            # one decode token per active sequence per round
            produced = 0
            for m in mbs:
                for s in m:
                    s.generated += 1
                    produced += 1
                m[:] = [s for s in m if s.generated < s.gen_target]
            t += rt
            rounds += 1
            if t >= counted_from:
                out_tokens += produced
                in_tokens += admitted

        window = c.sim_seconds - c.warmup_seconds
        util = 1.0 - sched_lib.bubble_fraction(c.n_stages, n_b, ts_now,
                                               c.lat_mean)
        m_g = 0.0
        if choice.offload:
            m_g = min(offload_lib.global_pool_bytes(c.offload_bandwidth,
                                                    ts_now),
                      c.m_kv_bytes / 2.0)
        return SimResult(
            output_tps=out_tokens / window,
            total_tps=(out_tokens + in_tokens) / window,
            n_microbatches=n_b,
            per_mb_batch=choice.per_mb_batch,
            utilisation=util,
            round_time=self._round_time(ts_now, n_b),
            stage_time=ts_now,
            m_g_bytes=m_g,
        )


def simulate_links(policy: str, link_latencies, *, time_scale: float = 1.0,
                   sim_seconds: float = 400.0, warmup: float = 100.0,
                   **overrides) -> SimResult:
    """DES prediction for one policy over an explicit heterogeneous ring —
    the cross-check the ``latency_curve`` benchmark runs against a
    :class:`repro.distributed.transport.DeploymentPlan`'s link latencies
    (``plan.link_latencies``)."""
    cfg = SimConfig(policy=policy, n_stages=len(link_latencies),
                    link_latencies=tuple(link_latencies),
                    time_scale=time_scale, sim_seconds=sim_seconds,
                    warmup_seconds=warmup, **overrides)
    return PipelineSimulator(cfg).run()


def calibrate(target_tps: float = 194.6, **overrides) -> float:
    """Find the single time-scale constant matching deserve_pp @ L≈0 to the
    paper's centralized number.  Returned scale is reused for every other
    (policy, latency) cell — those are predictions, not fits."""
    lo, hi = 0.05, 50.0
    for _ in range(40):
        mid = math.sqrt(lo * hi)
        cfg = SimConfig(policy="deserve_pp", latency=0.0, time_scale=mid,
                        sim_seconds=400, warmup_seconds=100, **overrides)
        tps = PipelineSimulator(cfg).run().output_tps
        if tps > target_tps:
            lo = mid
        else:
            hi = mid
    return math.sqrt(lo * hi)


def table4(time_scale: Optional[float] = None,
           latencies=(0.0, 0.016, 0.032, 0.064, 0.256),
           sim_seconds: float = 400.0, warmup: float = 100.0,
           **overrides) -> Dict[str, Dict[float, SimResult]]:
    """Run the full policy × latency grid of paper Table 4."""
    scale = time_scale if time_scale is not None else calibrate(**overrides)
    out: Dict[str, Dict[float, SimResult]] = {}
    for policy in ("vllm_pp", "deserve_pp", "deserve_opt"):
        out[policy] = {}
        for lat in latencies:
            cfg = SimConfig(policy=policy, latency=lat, time_scale=scale,
                            sim_seconds=sim_seconds, warmup_seconds=warmup,
                            **overrides)
            out[policy][lat] = PipelineSimulator(cfg).run()
    return out
