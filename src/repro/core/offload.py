"""KV-cache offloading (DeServe §4.2): capacity formulas + the double-buffer
global-pool swapper.

Formula 2 sizes each global pool so that a full swap (out + in, full-duplex)
hides under one pipeline stage time:      M_G = W · T_S
Formula 1 gives the per-microbatch KV capacity with offloading:
      M_B' = (M_KV − 2·M_G) / N_B + M_G
whose floor M_G is *independent of N_B* — the synergy that lets microbatch
scheduling (§4.3) add in-flight microbatches without starving batch size.

Hardware adaptation: on GPU the swap path is PCIe; on TPU v5e it is the
host-DMA path (HBM ↔ host DRAM).  The :class:`DoubleBufferOffloader` below
implements the *schedule* (pool parity, swap-out of the departing microbatch
overlapped with swap-in of the arriving one); on TPU the copies lower to
async device↔pinned_host DMAs, on CPU they are explicit numpy round-trips —
the bookkeeping and the schedule are identical, which is what the tests pin.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_cache import PoolConfig, global_slice

# default bandwidth constants (bytes/s)
PCIE4_BW = 24e9            # paper's setting: PCIe 4.0 x16 effective
TPU_HOST_DMA_BW = 32e9     # v5e host DMA (per chip, conservative)


def global_pool_bytes(bandwidth: float, stage_time: float) -> float:
    """Formula 2: the largest pool a stage-time-long swap can move."""
    return bandwidth * stage_time


def per_microbatch_capacity(m_kv: float, m_g: float, n_b: int) -> float:
    """Formula 1: per-microbatch KV bytes with offloading enabled."""
    m_g = min(m_g, m_kv / 2.0)
    return (m_kv - 2.0 * m_g) / n_b + m_g


def per_microbatch_capacity_no_offload(m_kv: float, n_b: int) -> float:
    return m_kv / n_b


def batch_size_from_capacity(capacity_bytes: float,
                             kv_bytes_per_seq: float) -> int:
    return max(0, int(capacity_bytes // max(kv_bytes_per_seq, 1.0)))


@dataclass
class OffloadPlan:
    """Concrete page accounting for an engine/pipeline stage."""
    pool: PoolConfig
    bandwidth: float
    stage_time: float
    n_microbatches: int
    page_bytes: int                   # bytes per page across paged layers

    @classmethod
    def derive(cls, *, m_kv_bytes: float, page_bytes: int, page_size: int,
               max_pages_per_seq: int, bandwidth: float, stage_time: float,
               n_microbatches: int) -> "OffloadPlan":
        m_g = global_pool_bytes(bandwidth, stage_time)
        m_g = min(m_g, m_kv_bytes / 2.0)
        n_global = int(m_g // page_bytes)
        n_local = max(2, int((m_kv_bytes - 2 * m_g) // page_bytes))
        pool = PoolConfig(page_size=page_size, n_local_pages=n_local,
                          n_global_pages=n_global,
                          max_pages_per_seq=max_pages_per_seq)
        return cls(pool=pool, bandwidth=bandwidth, stage_time=stage_time,
                   n_microbatches=n_microbatches, page_bytes=page_bytes)

    @property
    def m_g_bytes(self) -> float:
        return self.pool.n_global_pages * self.page_bytes

    @property
    def m_kv_bytes(self) -> float:
        return self.pool.n_pages * self.page_bytes

    def capacity_with_offload(self) -> float:
        return per_microbatch_capacity(self.m_kv_bytes, self.m_g_bytes,
                                       self.n_microbatches)

    def capacity_without_offload(self) -> float:
        return per_microbatch_capacity_no_offload(self.m_kv_bytes,
                                                  self.n_microbatches)


class DoubleBufferOffloader:
    """Functional double-buffer swapper over the engine's cache pytree.

    Microbatch ``m`` owns global pool parity ``m % 2``.  ``ensure_resident``
    swaps the departing microbatch's global-pool content to the host store
    and the arriving one's back in.  ``prefetch_next`` mirrors the paper's
    overlap: with pool ``G_p`` feeding compute for microbatch ``m``, pool
    ``G_{1−p}`` is being refilled for ``m+1`` — on TPU both directions run
    concurrently on the full-duplex host-DMA path.
    """

    def __init__(self, pool: PoolConfig, num_microbatches: int):
        self.pool = pool
        self.num_microbatches = num_microbatches
        self.resident: Dict[int, Optional[int]] = {0: None, 1: None}
        self._host: Dict[int, List[dict]] = {}
        self.swap_count = 0
        self.bytes_swapped = 0

    # -- internal: per-layer global slices ---------------------------------

    def _paged_layers(self, caches):
        for c in caches["scan"]:
            if isinstance(c, dict) and "k_pages" in c:
                yield c, 1            # pool axis after the period axis
        for c in caches["tail"]:
            if isinstance(c, dict) and "k_pages" in c:
                yield c, 0

    def ensure_resident(self, caches, mb: int):
        parity = mb % 2
        if self.resident[parity] == mb or self.pool.n_global_pages == 0:
            return caches
        out_mb = self.resident[parity]
        sl = global_slice(self.pool, parity)
        layers = list(self._paged_layers(caches))
        if out_mb is not None:
            store = []
            for c, axis in layers:
                k = jax.lax.slice_in_dim(c["k_pages"], sl.start, sl.stop, axis=axis)
                v = jax.lax.slice_in_dim(c["v_pages"], sl.start, sl.stop, axis=axis)
                # repro-audit: allow(host-sync) — §4.2 host swap is synchronous by design today; async device→pinned-host DMA overlap is ROADMAP item 4
                store.append({"k": np.asarray(k), "v": np.asarray(v)})
                self.bytes_swapped += k.nbytes + v.nbytes
            self._host[out_mb] = store

        incoming = self._host.get(mb)
        if incoming is None and out_mb is not None:
            # first touch for this microbatch while the pool holds another
            # one's content: zero-fill (hygiene — stale KV is masked by
            # seq_lens anyway, but must never be observable)
            incoming = []
            for c, axis in layers:
                shape = list(c["k_pages"].shape)
                shape[axis] = sl.stop - sl.start
                incoming.append({"k": np.zeros(shape, c["k_pages"].dtype),
                                 "v": np.zeros(shape, c["v_pages"].dtype)})
        out = {"scan": [], "tail": []}
        li = 0
        for part in ("scan", "tail"):
            for c in caches[part]:
                if isinstance(c, dict) and "k_pages" in c:
                    axis = 1 if part == "scan" else 0
                    if incoming is not None:
                        k_new = jnp.asarray(incoming[li]["k"])
                        v_new = jnp.asarray(incoming[li]["v"])
                        c = {**c,
                             "k_pages": jax.lax.dynamic_update_slice_in_dim(
                                 c["k_pages"], k_new.astype(c["k_pages"].dtype),
                                 sl.start, axis=axis),
                             "v_pages": jax.lax.dynamic_update_slice_in_dim(
                                 c["v_pages"], v_new.astype(c["v_pages"].dtype),
                                 sl.start, axis=axis)}
                        self.bytes_swapped += k_new.nbytes + v_new.nbytes
                    li += 1
                out[part].append(c)
        self.resident[parity] = mb
        self.swap_count += 1
        return out


# ---------------------------------------------------------------------------
# TPU memory-kind integration (backend-gated, see DESIGN.md §3)
# ---------------------------------------------------------------------------


def host_memory_available() -> bool:
    """True when the backend supports device↔pinned_host placement (TPU).
    XLA:CPU rejects compile-time host placement for replicated tensors
    (verified: "UNIMPLEMENTED: Side-effect ops cannot be replicated")."""
    return jax.default_backend() == "tpu"


def pool_shardings(mesh, spec, *, host: bool):
    """NamedSharding for a KV pool buffer; ``host=True`` places it in
    pinned host memory (the paper's CPU-RAM side of the PCIe swap)."""
    if host and host_memory_available():
        return jax.sharding.NamedSharding(mesh, spec,
                                          memory_kind="pinned_host")
    # None = the backend's default memory kind (CPU backends reject an
    # explicit "device" kind; TPU default is HBM, which is what we want)
    return jax.sharding.NamedSharding(mesh, spec)


def place_host_store(offloader: "DoubleBufferOffloader", mesh, spec):
    """Move the offloader's host store to pinned host buffers on TPU: the
    swap copies then lower to async DMA instead of numpy round-trips.  On
    CPU this is a no-op (the numpy store *is* host memory)."""
    if not host_memory_available():
        return offloader
    sh = pool_shardings(mesh, spec, host=True)
    offloader._host = {
        mb: [{k: jax.device_put(jnp.asarray(v), sh) for k, v in layer.items()}
             for layer in layers]
        for mb, layers in offloader._host.items()
    }
    return offloader
