"""KV-cache offloading (DeServe §4.2): capacity formulas + the double-buffer
global-pool swapper.

Formula 2 sizes each global pool so that a full swap (out + in, full-duplex)
hides under one pipeline stage time:      M_G = W · T_S
Formula 1 gives the per-microbatch KV capacity with offloading:
      M_B' = (M_KV − 2·M_G) / N_B + M_G
whose floor M_G is *independent of N_B* — the synergy that lets microbatch
scheduling (§4.3) add in-flight microbatches without starving batch size.

Hardware adaptation: on GPU the swap path is PCIe; on TPU v5e it is the
host-DMA path (HBM ↔ host DRAM).  The :class:`DoubleBufferOffloader` below
implements the *schedule* (pool parity, swap-out of the departing microbatch
overlapped with swap-in of the arriving one).  In the default async mode
(``async_swap=True``) the swap-out stores the *enqueued* jax copy — a
lazily-materialised device array (routed to ``pinned_host`` when
:func:`place_host_store` armed a host sharding on TPU) — so the D2H of
buffer A overlaps the next tick's jit computing into buffer B; nothing
blocks until :meth:`DoubleBufferOffloader.settle` (drain/reshard) or the
value is consumed by a swap-in.  ``async_swap=False`` keeps the old
blocking numpy round-trip for debugging and bit-exactness A/B runs — the
bookkeeping and the schedule are identical either way, which is what the
tests pin.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_cache import PoolConfig, global_slice

# default bandwidth constants (bytes/s)
PCIE4_BW = 24e9            # paper's setting: PCIe 4.0 x16 effective
TPU_HOST_DMA_BW = 32e9     # v5e host DMA (per chip, conservative)


def global_pool_bytes(bandwidth: float, stage_time: float) -> float:
    """Formula 2: the largest pool a stage-time-long swap can move."""
    return bandwidth * stage_time


def per_microbatch_capacity(m_kv: float, m_g: float, n_b: int) -> float:
    """Formula 1: per-microbatch KV bytes with offloading enabled."""
    m_g = min(m_g, m_kv / 2.0)
    return (m_kv - 2.0 * m_g) / n_b + m_g


def per_microbatch_capacity_no_offload(m_kv: float, n_b: int) -> float:
    return m_kv / n_b


def batch_size_from_capacity(capacity_bytes: float,
                             kv_bytes_per_seq: float) -> int:
    return max(0, int(capacity_bytes // max(kv_bytes_per_seq, 1.0)))


@dataclass
class OffloadPlan:
    """Concrete page accounting for an engine/pipeline stage."""
    pool: PoolConfig
    bandwidth: float
    stage_time: float
    n_microbatches: int
    page_bytes: int                   # bytes per page across paged layers

    @classmethod
    def derive(cls, *, m_kv_bytes: float, page_bytes: int, page_size: int,
               max_pages_per_seq: int, bandwidth: float, stage_time: float,
               n_microbatches: int) -> "OffloadPlan":
        m_g = global_pool_bytes(bandwidth, stage_time)
        m_g = min(m_g, m_kv_bytes / 2.0)
        n_global = int(m_g // page_bytes)
        n_local = max(2, int((m_kv_bytes - 2 * m_g) // page_bytes))
        pool = PoolConfig(page_size=page_size, n_local_pages=n_local,
                          n_global_pages=n_global,
                          max_pages_per_seq=max_pages_per_seq)
        return cls(pool=pool, bandwidth=bandwidth, stage_time=stage_time,
                   n_microbatches=n_microbatches, page_bytes=page_bytes)

    @property
    def m_g_bytes(self) -> float:
        return self.pool.n_global_pages * self.page_bytes

    @property
    def m_kv_bytes(self) -> float:
        return self.pool.n_pages * self.page_bytes

    def capacity_with_offload(self) -> float:
        return per_microbatch_capacity(self.m_kv_bytes, self.m_g_bytes,
                                       self.n_microbatches)

    def capacity_without_offload(self) -> float:
        return per_microbatch_capacity_no_offload(self.m_kv_bytes,
                                                  self.n_microbatches)


# jitted so the snapshot is one fused copy per buffer; static bounds:
# one compile per (pool shape, parity) — a handful total
@functools.partial(jax.jit, static_argnames=("start", "stop", "axis"))
def _snapshot_slice(pages, start: int, stop: int, axis: int):
    return jax.lax.slice_in_dim(pages, start, stop, axis=axis)


# one worker serialises stage-outs in submission order (the double-buffer
# schedule needs no more concurrency: at most one departing microbatch per
# parity is in flight); shared across offloaders — copies are bandwidth-
# bound, more workers would just contend for the same memory bus
_COPY_POOL: Optional[ThreadPoolExecutor] = None


def _copy_pool() -> ThreadPoolExecutor:
    global _COPY_POOL
    if _COPY_POOL is None:
        _COPY_POOL = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="kv-offload")
    return _COPY_POOL


class DoubleBufferOffloader:
    """Functional double-buffer swapper over the engine's cache pytree.

    Microbatch ``m`` owns global pool parity ``m % 2``.  ``ensure_resident``
    swaps the departing microbatch's global-pool content to the host store
    and the arriving one's back in.  ``prefetch_next`` mirrors the paper's
    overlap: with pool ``G_p`` feeding compute for microbatch ``m``, pool
    ``G_{1−p}`` is being refilled for ``m+1`` — on TPU both directions run
    concurrently on the full-duplex host-DMA path.

    ``async_swap=True`` (default): swap-out stores a *future* of the
    snapshot instead of performing it inline — jax arrays are immutable,
    so the slice taken on the copy worker is a correct snapshot of the
    pool at swap-out time while the engaged window only pays the submit.
    The future resolves at the matching swap-in (by which point the copy
    has long landed) or at :meth:`settle`.  Invariants the strict-mode
    auditor pins: ``resident[p]`` is ``None`` or has parity ``p``, the
    host store never keys a currently-resident microbatch, and the swap
    counters are monotone for the offloader's lifetime.
    """

    def __init__(self, pool: PoolConfig, num_microbatches: int,
                 async_swap: bool = True):
        self.pool = pool
        self.num_microbatches = num_microbatches
        self.async_swap = async_swap
        self.resident: Dict[int, Optional[int]] = {0: None, 1: None}
        # mb -> per-layer {"k","v"} store, or a Future of it (async mode)
        self._host: Dict[int, Union[List[dict], Future]] = {}
        self._host_sharding = None        # armed by place_host_store (TPU)
        self.swap_count = 0
        self.bytes_swapped = 0
        # flight recorder (set by the backend when tracing is on): swap
        # dispatches and swap-in wait windows are recorded host-side
        self.recorder = None

    # -- internal: per-layer global slices ---------------------------------

    def _paged_layers(self, caches):
        for c in caches["scan"]:
            if isinstance(c, dict) and "k_pages" in c:
                yield c, 1            # pool axis after the period axis
        for c in caches["tail"]:
            if isinstance(c, dict) and "k_pages" in c:
                yield c, 0

    def ensure_resident(self, caches, mb: int):
        parity = mb % 2
        if self.resident[parity] == mb or self.pool.n_global_pages == 0:
            return caches
        out_mb = self.resident[parity]
        sl = global_slice(self.pool, parity)
        layers = list(self._paged_layers(caches))
        rec = self.recorder
        if out_mb is not None:
            self._host[out_mb] = self._dispatch_stage_out(layers, sl)
            if rec is not None:
                rec.offload_swap_out(out_mb, time.perf_counter(),
                                     self.async_swap)

        incoming = self._host.pop(mb, None)
        if isinstance(incoming, Future):
            # the wait window: the part of the staged copy the
            # double-buffer failed to hide under the previous tick
            t0 = time.perf_counter()
            incoming = incoming.result()
            if rec is not None:
                rec.offload_swap_in(mb, t0, time.perf_counter())
        if incoming is None and out_mb is not None:
            # first touch for this microbatch while the pool holds another
            # one's content: zero-fill (hygiene — stale KV is masked by
            # seq_lens anyway, but must never be observable)
            incoming = []
            for c, axis in layers:
                shape = list(c["k_pages"].shape)
                shape[axis] = sl.stop - sl.start
                incoming.append({"k": np.zeros(shape, c["k_pages"].dtype),
                                 "v": np.zeros(shape, c["v_pages"].dtype)})
        out = {"scan": [], "tail": []}
        li = 0
        for part in ("scan", "tail"):
            for c in caches[part]:
                if isinstance(c, dict) and "k_pages" in c:
                    axis = 1 if part == "scan" else 0
                    if incoming is not None:
                        k_new = jnp.asarray(incoming[li]["k"])
                        v_new = jnp.asarray(incoming[li]["v"])
                        c = {**c,
                             "k_pages": jax.lax.dynamic_update_slice_in_dim(
                                 c["k_pages"], k_new.astype(c["k_pages"].dtype),
                                 sl.start, axis=axis),
                             "v_pages": jax.lax.dynamic_update_slice_in_dim(
                                 c["v_pages"], v_new.astype(c["v_pages"].dtype),
                                 sl.start, axis=axis)}
                        self.bytes_swapped += k_new.nbytes + v_new.nbytes
                    li += 1
                out[part].append(c)
        self.resident[parity] = mb
        self.swap_count += 1
        return out

    def _dispatch_stage_out(self, layers, sl) -> Union[List[dict], Future]:
        """Swap-out dispatch: async mode hands the snapshot to the copy
        worker and returns the in-flight :class:`Future` (the tick loop
        never blocks on it — swap-in or :meth:`settle` resolves it);
        sync mode pays the copy here."""
        if self.async_swap:
            return _copy_pool().submit(self._stage_out, layers, sl)
        return self._stage_out(layers, sl)

    def _stage_out(self, layers, sl) -> List[dict]:
        """Snapshot the departing microbatch's global slices into the
        host store.  This is the D2H half of the swap — the part the
        async mode turns from a blocking copy into an enqueued one."""
        store = []
        for c, axis in layers:
            k = _snapshot_slice(c["k_pages"], sl.start, sl.stop, axis)
            v = _snapshot_slice(c["v_pages"], sl.start, sl.stop, axis)
            if self.async_swap:
                if self._host_sharding is not None:
                    # TPU: enqueue the D2H DMA toward pinned_host now;
                    # it lands while the next tick jit runs
                    k = jax.device_put(k, self._host_sharding)
                    v = jax.device_put(v, self._host_sharding)
                store.append({"k": k, "v": v})
            else:
                # repro-audit: allow(host-sync, offload-sync) — async_swap=False opt-out: the blocking numpy round-trip, kept for debugging and A/B bit-exactness runs
                store.append({"k": np.asarray(k), "v": np.asarray(v)})
            self.bytes_swapped += k.nbytes + v.nbytes
        return store

    def settle(self) -> "DoubleBufferOffloader":
        """Block until every in-flight host-store copy has landed (and
        replace resolved futures with their stores).  This is the
        *outside-the-engaged-window* barrier (drain / reshard /
        shutdown) — the tick loop itself never calls it, so the async
        copies stay overlapped with compute."""
        for mb, layers in list(self._host.items()):
            if isinstance(layers, Future):
                self._host[mb] = layers = layers.result()
            for layer in layers:
                for arr in layer.values():
                    if isinstance(arr, jax.Array):
                        jax.block_until_ready(arr)
        return self


# ---------------------------------------------------------------------------
# TPU memory-kind integration (backend-gated, see DESIGN.md §3)
# ---------------------------------------------------------------------------


def host_memory_available() -> bool:
    """True when the backend supports device↔pinned_host placement (TPU).
    XLA:CPU rejects compile-time host placement for replicated tensors
    (verified: "UNIMPLEMENTED: Side-effect ops cannot be replicated")."""
    return jax.default_backend() == "tpu"


def pool_shardings(mesh, spec, *, host: bool):
    """NamedSharding for a KV pool buffer; ``host=True`` places it in
    pinned host memory (the paper's CPU-RAM side of the PCIe swap)."""
    if host and host_memory_available():
        return jax.sharding.NamedSharding(mesh, spec,
                                          memory_kind="pinned_host")
    # None = the backend's default memory kind (CPU backends reject an
    # explicit "device" kind; TPU default is HBM, which is what we want)
    return jax.sharding.NamedSharding(mesh, spec)


def place_host_store(offloader: "DoubleBufferOffloader", mesh, spec):
    """Move the offloader's host store to pinned host buffers on TPU and
    arm the sharding so future async swap-outs enqueue device→pinned_host
    DMAs directly.  On CPU this is a no-op (the numpy / jax store *is*
    host memory)."""
    if not host_memory_available():
        return offloader
    sh = pool_shardings(mesh, spec, host=True)
    offloader._host_sharding = sh
    offloader.settle()                    # resolve in-flight futures first
    offloader._host = {
        mb: [{k: jax.device_put(jnp.asarray(v), sh) for k, v in layer.items()}
             for layer in layers]
        for mb, layers in offloader._host.items()
    }
    return offloader
